//! CSV export of the figure data series — plot-ready files for anyone
//! regenerating the paper's graphs (`stt-ai figures --csv-dir out/`).

use std::io::Write;
use std::path::Path;

use crate::accel::ArrayConfig;
use crate::dse::capacity::{CapacityRow, DramOverheadRow};
use crate::dse::delta::DeltaSweep;
use crate::dse::{energy_area, retention, scratchpad::PartialOfmapRow};
use crate::memsys::DramModel;
use crate::models::{self, DType};
use crate::mram::MtjTech;
use crate::util::units::MB;

fn write_csv(path: &Path, header: &str, rows: &[String]) -> std::io::Result<()> {
    let mut f = std::fs::File::create(path)?;
    writeln!(f, "{header}")?;
    for r in rows {
        writeln!(f, "{r}")?;
    }
    Ok(())
}

/// Export every figure's data series as CSVs into `dir`.
/// Returns the list of files written.
pub fn export_all(dir: &Path) -> std::io::Result<Vec<String>> {
    std::fs::create_dir_all(dir)?;
    let zoo = models::zoo();
    let mut written = Vec::new();
    let mut emit = |name: &str, header: &str, rows: Vec<String>| -> std::io::Result<()> {
        write_csv(&dir.join(name), header, &rows)?;
        written.push(name.to_string());
        Ok(())
    };

    // Fig. 10.
    emit(
        "fig10_model_sizes.csv",
        "model,int8_bytes,bf16_bytes,fmap_min,fmap_max,weight_min,weight_max",
        zoo.iter()
            .map(|m| {
                let r = CapacityRow::analyze(m, DType::Bf16, &[1]);
                format!(
                    "{},{},{},{},{},{},{}",
                    r.model, r.size_int8, r.size_bf16, r.fmap_min, r.fmap_max, r.weight_min, r.weight_max
                )
            })
            .collect(),
    )?;

    // Fig. 11.
    let mut rows = Vec::new();
    for m in &zoo {
        for b in [1u64, 2, 4, 8] {
            rows.push(format!(
                "{},{},{},{}",
                m.name,
                b,
                m.max_conv_working_set(DType::Int8, b),
                m.max_conv_working_set(DType::Bf16, b)
            ));
        }
    }
    emit("fig11_glb_capacity.csv", "model,batch,int8_bytes,bf16_bytes", rows)?;

    // Fig. 12.
    let a = ArrayConfig::paper_42x42();
    let dram = DramModel::ddr4_2933_dual();
    let mut rows = Vec::new();
    for m in &zoo {
        for dt in [DType::Int8, DType::Bf16] {
            for b in [1u64, 2, 4, 8] {
                let r = DramOverheadRow::analyze(m, &a, &dram, dt, b, 12 * MB);
                rows.push(format!(
                    "{},{},{},{},{:.6e},{:.6e}",
                    r.model,
                    r.dtype_bytes * 8,
                    b,
                    r.spill_bytes,
                    r.extra_latency,
                    r.extra_energy
                ));
            }
        }
    }
    emit("fig12_dram_overhead.csv", "model,dtype_bits,batch,spill_bytes,latency_s,energy_j", rows)?;

    // Fig. 13.
    emit(
        "fig13_retention.csv",
        "model,min_t_ret_s,max_t_ret_s",
        retention::fig13(&zoo)
            .iter()
            .map(|r| format!("{},{:.6e},{:.6e}", r.model, r.min_t_ret, r.max_t_ret))
            .collect(),
    )?;

    // Fig. 14.
    emit(
        "fig14a_retention_vs_array.csv",
        "macs,max_t_ret_s",
        retention::fig14a(&zoo, &[14, 28, 42, 56, 84])
            .iter()
            .map(|(m, t)| format!("{m},{t:.6e}"))
            .collect(),
    )?;
    emit(
        "fig14b_retention_vs_batch.csv",
        "batch,max_t_ret_s",
        retention::fig14b(&zoo, &[1, 2, 4, 8, 16, 32])
            .iter()
            .map(|(b, t)| format!("{b},{t:.6e}"))
            .collect(),
    )?;

    // Fig. 15 / 17 sweeps.
    for (name, tech, ber) in [
        ("fig15_sakhare2020_1e-8.csv", MtjTech::sakhare2020(), 1e-8),
        ("fig15_wei2019_1e-8.csv", MtjTech::wei2019(), 1e-8),
        ("fig17_wei2019_1e-5.csv", MtjTech::wei2019(), 1e-5),
    ] {
        let s = DeltaSweep::run(tech, ber, &DeltaSweep::default_deltas());
        let rows = s
            .retention
            .iter()
            .zip(&s.read_pulse)
            .zip(&s.write_pulse)
            .map(|((r, rp), wp)| format!("{},{:.6e},{:.6e},{:.6e}", r.0, r.1, rp.1, wp.1))
            .collect();
        emit(name, "delta,retention_s,read_pulse_s,write_pulse_s", rows)?;
    }

    // Fig. 16.
    let caps = energy_area::default_capacities_mb();
    for (name, rows) in [
        ("fig16_glb_27p5.csv", energy_area::fig16_glb(&caps)),
        ("fig16_lsb_17p5.csv", energy_area::fig16_lsb(&caps)),
    ] {
        emit(
            name,
            "capacity_bytes,sram_energy_j,mram_energy_j,sram_area_mm2,mram_area_mm2",
            rows.iter()
                .map(|r| {
                    format!(
                        "{},{:.6e},{:.6e},{:.6},{:.6}",
                        r.capacity_bytes, r.sram_energy, r.mram_energy, r.sram_area, r.mram_area
                    )
                })
                .collect(),
        )?;
    }

    // Fig. 18.
    emit(
        "fig18_partial_ofmaps.csv",
        "model,bf16_bytes,int8_bytes",
        zoo.iter()
            .map(|m| {
                let r = PartialOfmapRow::analyze(m);
                format!("{},{},{}", r.model, r.bf16_bytes, r.int8_bytes)
            })
            .collect(),
    )?;

    // Table III.
    emit(
        "table3_accelerators.csv",
        "accelerator,area_mm2,dynamic_mw,leakage_mw",
        super::table3_rows()
            .iter()
            .map(|r| format!("{},{:.4},{:.3},{:.4}", r.name, r.area_mm2, r.dynamic_mw, r.leakage_mw))
            .collect(),
    )?;

    Ok(written)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exports_all_figures() {
        let dir = std::env::temp_dir().join("stt_ai_csv_test");
        let files = export_all(&dir).unwrap();
        assert!(files.len() >= 12, "{files:?}");
        for f in &files {
            let text = std::fs::read_to_string(dir.join(f)).unwrap();
            let lines: Vec<&str> = text.lines().collect();
            assert!(lines.len() >= 2, "{f} must have header + data");
            let cols = lines[0].split(',').count();
            for l in &lines[1..] {
                assert_eq!(l.split(',').count(), cols, "{f}: ragged row {l}");
            }
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn fig13_csv_parses_back() {
        let dir = std::env::temp_dir().join("stt_ai_csv_test2");
        export_all(&dir).unwrap();
        let text = std::fs::read_to_string(dir.join("fig13_retention.csv")).unwrap();
        let data_rows = text.lines().skip(1).count();
        assert_eq!(data_rows, 19);
        for l in text.lines().skip(1) {
            let parts: Vec<&str> = l.split(',').collect();
            let min: f64 = parts[1].parse().unwrap();
            let max: f64 = parts[2].parse().unwrap();
            assert!(min <= max);
        }
        std::fs::remove_dir_all(&dir).ok();
    }
}
