//! Figure renderers for §V, driven by the unified `dse::engine` sweeps.
//!
//! Every `fig*` function builds its figure's [`SweepSpec`], evaluates it on
//! the [`Runner`]'s work-stealing pool (deterministic, ordered results) and
//! prints the paper-shaped table from the unified [`SweepResult`] records.
//! The text is **byte-identical** to the frozen pre-refactor renderers in
//! [`super::legacy`] — `tests/figures.rs` asserts this for every figure —
//! while regeneration fans out over all cores and obeys `--sweep` axis
//! overrides.
//!
//! `fig*` entry points keep the old one-argument signature (auto-sized
//! pool); `fig*_with` take an explicit [`Runner`] for `--parallel N` and
//! axis overrides.

use std::io::Write;

use crate::dse::delta::paper_design_points;
use crate::dse::engine::{self, Axis, Runner, SweepResult, SweepSpec};
use crate::models::DType;
use crate::mram::MtjTech;
use crate::util::pool::ThreadPool;
use crate::util::units::{fmt_bytes, fmt_time, KB, MB};

fn u64_axis(spec: &SweepSpec, name: &str, default: &[u64]) -> Vec<u64> {
    match spec.axis(name) {
        Some(Axis::Batch(v)) | Some(Axis::GlbMb(v)) | Some(Axis::Macs(v)) => v.clone(),
        _ => default.to_vec(),
    }
}

fn f64_axis(spec: &SweepSpec, name: &str, default: &[f64]) -> Vec<f64> {
    match spec.axis(name) {
        Some(Axis::Delta(v)) | Some(Axis::Ber(v)) => v.clone(),
        _ => default.to_vec(),
    }
}

/// Fig. 10: model sizes + conv fmap/weight ranges.
pub fn fig10(w: &mut impl Write) -> std::io::Result<Vec<SweepResult>> {
    fig10_with(w, &Runner::default())
}

pub fn fig10_with(w: &mut impl Write, r: &Runner) -> std::io::Result<Vec<SweepResult>> {
    writeln!(w, "== Fig. 10: model sizes and conv fmap/weight ranges ==")?;
    writeln!(
        w,
        "{:<14} {:>10} {:>10} {:>12} {:>12} {:>12} {:>12}",
        "model", "int8", "bf16", "fmap-min", "fmap-max", "wt-min", "wt-max"
    )?;
    let rows = r.run(engine::spec_fig10(&engine::shared_zoo()));
    for rec in &rows {
        writeln!(
            w,
            "{:<14} {:>10} {:>10} {:>12} {:>12} {:>12} {:>12}",
            rec.point.model.as_deref().unwrap(),
            fmt_bytes(rec.metric_u64("int8_bytes")),
            fmt_bytes(rec.metric_u64("bf16_bytes")),
            rec.metric_u64("fmap_min"),
            rec.metric_u64("fmap_max"),
            rec.metric_u64("weight_min"),
            rec.metric_u64("weight_max")
        )?;
    }
    let total: u64 = rows.iter().map(|x| x.metric_u64("bf16_bytes")).sum();
    writeln!(w, "-- zoo total bf16 {} (paper: ~280 MB NVM for bf16 class)", fmt_bytes(total))?;
    Ok(rows)
}

/// Fig. 11: required GLB capacity vs batch size.
pub fn fig11(w: &mut impl Write) -> std::io::Result<Vec<SweepResult>> {
    fig11_with(w, &Runner::default())
}

pub fn fig11_with(w: &mut impl Write, r: &Runner) -> std::io::Result<Vec<SweepResult>> {
    let spec = r.resolve(engine::spec_fig11(&engine::shared_zoo()));
    let batches = u64_axis(&spec, "batch", &[1, 2, 4, 8]);
    let rows = spec.run(r.pool());
    writeln!(w, "== Fig. 11: required GLB capacity (int8 | bf16) vs batch ==")?;
    let heads: Vec<String> = batches.iter().map(|b| b.to_string()).collect();
    let head = format!("batch: {}  (int8, bf16)", heads.join(" | "));
    writeln!(w, "{:<14} {head}", "model")?;
    for chunk in rows.chunks(batches.len()) {
        let mut line = format!("{:<14}", chunk[0].point.model.as_deref().unwrap());
        for rec in chunk {
            line += &format!(
                " {:>9}/{:<9}",
                fmt_bytes(rec.metric_u64("int8_bytes")),
                fmt_bytes(rec.metric_u64("bf16_bytes"))
            );
        }
        writeln!(w, "{line}")?;
    }
    let n_models = rows.len() / batches.len();
    for (bi, &b) in batches.iter().enumerate() {
        let need = rows
            .iter()
            .skip(bi)
            .step_by(batches.len())
            .map(|x| x.metric_u64("int8_bytes"))
            .max()
            .unwrap_or(0);
        let served = rows
            .iter()
            .skip(bi)
            .step_by(batches.len())
            .filter(|x| x.metric_u64("int8_bytes") <= 12 * MB)
            .count();
        writeln!(
            w,
            "-- batch {b}: zoo-max int8 {} ; 12 MB serves {served}/{n_models}",
            fmt_bytes(need)
        )?;
    }
    Ok(rows)
}

/// Fig. 12: extra DRAM latency/energy with a 12 MB GLB.
pub fn fig12(w: &mut impl Write) -> std::io::Result<Vec<SweepResult>> {
    fig12_with(w, &Runner::default())
}

pub fn fig12_with(w: &mut impl Write, r: &Runner) -> std::io::Result<Vec<SweepResult>> {
    let spec = r.resolve(engine::spec_fig12(&engine::shared_zoo()));
    // The paper's table shows the largest swept batch (8 by default).
    let show = *u64_axis(&spec, "batch", &[1, 2, 4, 8]).last().unwrap();
    let rows = spec.run(r.pool());
    writeln!(w, "== Fig. 12: extra DRAM access latency/energy (12 MB GLB) ==")?;
    let mut cur: Option<DType> = None;
    for rec in &rows {
        let dt = rec.point.dtype.unwrap();
        if cur != Some(dt) {
            cur = Some(dt);
            writeln!(w, "-- dtype {dt:?}")?;
            writeln!(
                w,
                "{:<14} {:>6} {:>12} {:>12} {:>12}",
                "model", "batch", "spill", "latency", "energy"
            )?;
        }
        if rec.point.batch == Some(show) {
            writeln!(
                w,
                "{:<14} {:>6} {:>12} {:>10.3}ms {:>10.3}mJ",
                rec.point.model.as_deref().unwrap(),
                rec.point.batch.unwrap(),
                fmt_bytes(rec.metric_u64("spill_bytes")),
                rec.metric("latency_s") * 1e3,
                rec.metric("energy_j") * 1e3
            )?;
        }
    }
    Ok(rows)
}

/// Fig. 13: GLB retention range per model (42×42 MACs, batch 16, bf16).
pub fn fig13(w: &mut impl Write) -> std::io::Result<Vec<SweepResult>> {
    fig13_with(w, &Runner::default())
}

pub fn fig13_with(w: &mut impl Write, r: &Runner) -> std::io::Result<Vec<SweepResult>> {
    writeln!(w, "== Fig. 13: GLB retention time range (42x42 MACs, batch 16) ==")?;
    let rows = r.run(engine::spec_fig13(&engine::shared_zoo()));
    for rec in &rows {
        writeln!(
            w,
            "{:<14} min {:>12}  max {:>12}",
            rec.point.model.as_deref().unwrap(),
            fmt_time(rec.metric("min_t_ret_s")),
            fmt_time(rec.metric("max_t_ret_s"))
        )?;
    }
    let worst = rows.iter().map(|x| x.metric("max_t_ret_s")).fold(0.0, f64::max);
    writeln!(w, "-- worst case {} (paper: < 1.5 s, most < 0.5 s)", fmt_time(worst))?;
    Ok(rows)
}

/// Fig. 14: max retention vs MAC-array size (a) and batch (b).
pub fn fig14(w: &mut impl Write) -> std::io::Result<Vec<SweepResult>> {
    fig14_with(w, &Runner::default())
}

pub fn fig14_with(w: &mut impl Write, r: &Runner) -> std::io::Result<Vec<SweepResult>> {
    let zoo = engine::shared_zoo();
    let spec_a = r.resolve(engine::spec_fig14a(&zoo));
    let macs = u64_axis(&spec_a, "macs", &[14, 28, 42, 56, 84]);
    let rows_a = spec_a.run(r.pool());
    writeln!(w, "== Fig. 14a: max retention vs MAC array (batch 16) ==")?;
    for (gi, group) in rows_a.chunks(rows_a.len() / macs.len()).enumerate() {
        let worst = group.iter().map(|x| x.metric("max_t_ret_s")).fold(0.0, f64::max);
        let m = macs[gi];
        writeln!(w, "  {m}x{m} MACs: {}", fmt_time(worst))?;
    }
    let spec_b = r.resolve(engine::spec_fig14b(&zoo));
    let batches = u64_axis(&spec_b, "batch", &[1, 2, 4, 8, 16, 32]);
    let rows_b = spec_b.run(r.pool());
    writeln!(w, "== Fig. 14b: max retention vs batch (42x42) ==")?;
    for (gi, group) in rows_b.chunks(rows_b.len() / batches.len()).enumerate() {
        let worst = group.iter().map(|x| x.metric("max_t_ret_s")).fold(0.0, f64::max);
        writeln!(w, "  batch {}: {}", batches[gi], fmt_time(worst))?;
    }
    Ok(rows_a.into_iter().chain(rows_b).collect())
}

/// Fig. 15: Δ scaling panels for both silicon base cases.
pub fn fig15(w: &mut impl Write) -> std::io::Result<Vec<SweepResult>> {
    fig15_with(w, &Runner::default())
}

pub fn fig15_with(w: &mut impl Write, r: &Runner) -> std::io::Result<Vec<SweepResult>> {
    let spec = r.resolve(engine::spec_fig15());
    let deltas = f64_axis(&spec, "delta", &[]);
    let rows = spec.run(r.pool());
    writeln!(w, "== Fig. 15: thermal-stability scaling ==")?;
    for pts in paper_design_points(MtjTech::sakhare2020()) {
        writeln!(
            w,
            "  {:<22} Δ={:<5.1} Δ_GB={:<5.1} t_w={} t_r={} ret={}",
            pts.label,
            pts.delta_scaled,
            pts.delta_guard_banded,
            fmt_time(pts.write_pulse),
            fmt_time(pts.read_pulse),
            fmt_time(pts.achieved_retention)
        )?;
    }
    let ber = 1.0e-8_f64;
    for group in rows.chunks(deltas.len()) {
        let tech = group[0].point.tech.unwrap();
        writeln!(w, "-- base case {} @ BER {ber:.0e}: Δ grid {} points", tech.name(), deltas.len())?;
        for d in [12.5, 19.5, 27.5, 39.0, 55.0, 60.0] {
            // Showcase rows only for Δ values the (possibly overridden)
            // grid actually contains — never attribute another Δ's physics.
            if let Some(i) = deltas.iter().position(|&x| (x - d).abs() < 0.6) {
                writeln!(
                    w,
                    "   Δ≈{:<5} retention {:>12}  read {:>10}  write {:>10}",
                    d,
                    fmt_time(group[i].metric("retention_s")),
                    fmt_time(group[i].metric("read_pulse_s")),
                    fmt_time(group[i].metric("write_pulse_s"))
                )?;
            }
        }
    }
    Ok(rows)
}

/// Fig. 16: SRAM vs MRAM energy & area across capacities.
pub fn fig16(w: &mut impl Write) -> std::io::Result<Vec<SweepResult>> {
    fig16_with(w, &Runner::default())
}

pub fn fig16_with(w: &mut impl Write, r: &Runner) -> std::io::Result<Vec<SweepResult>> {
    writeln!(w, "== Fig. 16: SRAM vs STT-MRAM energy/area vs capacity ==")?;
    let spec = r.resolve(engine::spec_fig16());
    let deltas = f64_axis(&spec, "delta", &[27.5, 17.5]);
    let rows = spec.run(r.pool());
    for (gi, group) in rows.chunks(rows.len() / deltas.len()).enumerate() {
        // Default two-point sweep: robust GLB bank first, relaxed LSB last.
        let bank = if gi == 0 { "GLB" } else if gi + 1 == deltas.len() { "LSB" } else { "Δ" };
        writeln!(w, "-- {bank} Δ_GB={}", deltas[gi])?;
        writeln!(
            w,
            "{:>6} {:>12} {:>12} {:>8} {:>10} {:>10} {:>8}",
            "MB", "E_sram", "E_mram", "Ex", "A_sram", "A_mram", "Ax"
        )?;
        for rec in group {
            let (e_sram, e_mram) = (rec.metric("sram_energy_j"), rec.metric("mram_energy_j"));
            let (a_sram, a_mram) = (rec.metric("sram_area_mm2"), rec.metric("mram_area_mm2"));
            writeln!(
                w,
                "{:>6} {:>10.1}pJ {:>10.1}pJ {:>7.2}x {:>8.3}mm2 {:>8.3}mm2 {:>7.1}x",
                rec.point.glb_mb.unwrap(),
                e_sram * 1e12,
                e_mram * 1e12,
                e_sram / e_mram,
                a_sram,
                a_mram,
                a_sram / a_mram
            )?;
        }
    }
    Ok(rows)
}

/// Fig. 17: Δ scaling with relaxed BER (LSB bank).
pub fn fig17(w: &mut impl Write) -> std::io::Result<Vec<SweepResult>> {
    fig17_with(w, &Runner::default())
}

pub fn fig17_with(w: &mut impl Write, r: &Runner) -> std::io::Result<Vec<SweepResult>> {
    writeln!(w, "== Fig. 17: Δ scaling at relaxed BER 1e-5 (LSB bank, base [13]) ==")?;
    let spec = r.resolve(engine::spec_fig17());
    let bers = f64_axis(&spec, "ber", &[1.0e-5, 1.0e-8]);
    let deltas = f64_axis(&spec, "delta", &[]);
    let rows = spec.run(r.pool());
    let groups: Vec<&[SweepResult]> = rows.chunks(deltas.len()).collect();
    let (relaxed, tight) = (groups[0], *groups.last().unwrap());
    // Label the comparison with the actual tightest swept BER (1e-8 by
    // default), so an overridden ber axis never misattributes the baseline.
    let tight_ber = *bers.last().unwrap();
    for d in [12.5, 17.5, 27.5] {
        if let Some(i) = deltas.iter().position(|&x| (x - d).abs() < 0.6) {
            writeln!(
                w,
                "  Δ≈{:<5} ret {:>10} (vs {:>10} @{tight_ber:e})  write {:>10} (vs {:>10})",
                d,
                fmt_time(relaxed[i].metric("retention_s")),
                fmt_time(tight[i].metric("retention_s")),
                fmt_time(relaxed[i].metric("write_pulse_s")),
                fmt_time(tight[i].metric("write_pulse_s"))
            )?;
        }
    }
    Ok(rows)
}

/// Fig. 18: max partial-ofmap sizes.
pub fn fig18(w: &mut impl Write) -> std::io::Result<Vec<SweepResult>> {
    fig18_with(w, &Runner::default())
}

pub fn fig18_with(w: &mut impl Write, r: &Runner) -> std::io::Result<Vec<SweepResult>> {
    writeln!(w, "== Fig. 18: max partial-ofmap size per model ==")?;
    let rows = r.run(engine::spec_fig18(&engine::shared_zoo()));
    let mut fit = 0;
    for rec in &rows {
        let bf16 = rec.metric_u64("bf16_bytes");
        let ok = bf16 <= 52 * KB;
        if ok {
            fit += 1;
        }
        writeln!(
            w,
            "{:<14} bf16 {:>10}  int8 {:>10}  {}",
            rec.point.model.as_deref().unwrap(),
            fmt_bytes(bf16),
            fmt_bytes(rec.metric_u64("int8_bytes")),
            if ok { "fits 52 KB" } else { "exceeds 52 KB" }
        )?;
    }
    writeln!(w, "-- {fit}/{} fit the 52 KB bf16 scratchpad (26 KB int8)", rows.len())?;
    Ok(rows)
}

/// Fig. 19: buffer energy SRAM / MRAM / MRAM+scratchpad (ResNet-50).
pub fn fig19(w: &mut impl Write) -> std::io::Result<Vec<SweepResult>> {
    fig19_with(w, &Runner::default())
}

pub fn fig19_with(w: &mut impl Write, r: &Runner) -> std::io::Result<Vec<SweepResult>> {
    let rows = r.run(engine::spec_fig19(&engine::shared_zoo()));
    let rec = &rows[0];
    let name = rec.point.model.as_deref().unwrap();
    // The paper's display name for the default subject.
    let display = if name == "ResNet50" { "ResNet-50" } else { name };
    writeln!(
        w,
        "== Fig. 19: buffer energy per inference batch ({display}, batch {}) ==",
        rec.point.batch.unwrap()
    )?;
    let base = engine::ledger_total(rec, "sram");
    for (label, tag) in [("SRAM", "sram"), ("MRAM", "mram"), ("MRAM+scratchpad", "mram_sp")] {
        let total = engine::ledger_total(rec, tag);
        writeln!(
            w,
            "  {:<16} total {:>10.3} mJ (norm {:.3})  [rd {:.3} wr {:.3} sp {:.3} dram {:.3} mJ]",
            label,
            total * 1e3,
            total / base,
            rec.metric(engine::ledger_metric(tag, "glb_read_j")) * 1e3,
            rec.metric(engine::ledger_metric(tag, "glb_write_j")) * 1e3,
            rec.metric(engine::ledger_metric(tag, "scratchpad_j")) * 1e3,
            rec.metric(engine::ledger_metric(tag, "dram_j")) * 1e3
        )?;
    }
    Ok(rows)
}

/// Cross-technology GLB comparison table: every registered memory
/// technology building the 12 MB GLB at its default design point, at
/// inference-like and training-like write intensities (ResNet-50 traffic).
pub fn techcmp(w: &mut impl Write) -> std::io::Result<Vec<SweepResult>> {
    techcmp_with(w, &Runner::default())
}

pub fn techcmp_with(w: &mut impl Write, r: &Runner) -> std::io::Result<Vec<SweepResult>> {
    let rows = r.run(engine::spec_techcmp(&engine::shared_zoo()));
    writeln!(w, "== Cross-technology GLB comparison (12 MB, ResNet-50 batch 16) ==")?;
    writeln!(
        w,
        "{:<14} {:>4} {:>9} {:>9} {:>9} {:>9} {:>11} {:>11}",
        "tech", "wi", "area", "leak", "E_rd", "E_wr", "t_write", "E_buffer"
    )?;
    for rec in &rows {
        writeln!(
            w,
            "{:<14} {:>4} {:>6.2}mm2 {:>7.3}mW {:>7.1}pJ {:>7.1}pJ {:>11} {:>9.3}mJ",
            rec.point.tech.unwrap().name(),
            rec.point.write_intensity.unwrap(),
            rec.metric("glb_area_mm2"),
            rec.metric("glb_leakage_mw"),
            rec.metric("read_energy_j") * 1e12,
            rec.metric("write_energy_j") * 1e12,
            fmt_time(rec.metric("write_pulse_s")),
            rec.metric("buffer_energy_j") * 1e3
        )?;
    }
    // Headline: the buffer-energy winner at each swept intensity (derived
    // from the rows, so `--sweep write_intensity=...` overrides stay
    // covered).
    let mut wis: Vec<f64> = rows.iter().filter_map(|x| x.point.write_intensity).collect();
    wis.sort_by(f64::total_cmp);
    wis.dedup();
    for wi in wis {
        if let Some(best) = rows
            .iter()
            .filter(|x| x.point.write_intensity == Some(wi))
            .min_by(|a, b| {
                a.metric("buffer_energy_j").total_cmp(&b.metric("buffer_energy_j"))
            })
        {
            writeln!(
                w,
                "-- write intensity {wi}: lowest buffer energy {} ({:.3} mJ)",
                best.point.tech.unwrap().name(),
                best.metric("buffer_energy_j") * 1e3
            )?;
        }
    }
    Ok(rows)
}

/// Write-bandwidth stall comparison table: the three GLB organizations on
/// the ResNet-50 serving workload across the 42×42 / 84×84 arrays and
/// inference / training write intensities — where (and whether) MRAM write
/// pulses actually hide behind compute.
pub fn stall(w: &mut impl Write) -> std::io::Result<Vec<SweepResult>> {
    stall_with(w, &Runner::default())
}

pub fn stall_with(w: &mut impl Write, r: &Runner) -> std::io::Result<Vec<SweepResult>> {
    let rows = r.run(engine::spec_stall(&engine::shared_zoo()));
    // The header shows the (usually single-valued) model axis of the first
    // row — the fig19 convention — while per-row columns carry every axis a
    // `--sweep` override or `--from-selection` pin can reshape (glb, Δ), so
    // multi-valued rows stay attributable.
    let model = rows
        .first()
        .and_then(|x| x.point.model.clone())
        .unwrap_or_else(|| "ResNet50".into());
    writeln!(w, "== Write-bandwidth stalls ({model}, batch 16) ==")?;
    writeln!(
        w,
        "{:<14} {:>5} {:>4} {:>4} {:>5} {:>10} {:>10} {:>10} {:>10} {:>7} {:>10}",
        "variant", "macs", "wi", "glb", "dGB", "compute", "stall", "spill", "latency", "stall%",
        "wr-BW"
    )?;
    for rec in &rows {
        writeln!(
            w,
            "{:<14} {:>5} {:>4} {:>4} {:>5} {:>10} {:>10} {:>10} {:>10} {:>6.2}% {:>7.2}GB/s",
            rec.point.variant.map_or("?", engine::variant_label),
            rec.point.macs.unwrap_or(42),
            rec.point.write_intensity.unwrap_or(1.0),
            rec.point.glb_mb.unwrap_or(12),
            rec.point.delta.unwrap_or(27.5),
            fmt_time(rec.metric("compute_latency_s")),
            fmt_time(rec.metric("stall_s")),
            fmt_time(rec.metric("spill_s")),
            fmt_time(rec.metric("latency_s")),
            rec.metric("stall_frac_of_latency") * 100.0,
            rec.metric("glb_write_bw_bytes_per_s") / 1e9
        )?;
    }
    // Headline: worst unhidden share per swept array size.
    let mut sizes: Vec<u64> = rows.iter().filter_map(|x| x.point.macs).collect();
    sizes.sort_unstable();
    sizes.dedup();
    for macs in sizes {
        if let Some(worst) = rows.iter().filter(|x| x.point.macs == Some(macs)).max_by(|a, b| {
            a.metric("stall_frac_of_latency").total_cmp(&b.metric("stall_frac_of_latency"))
        }) {
            writeln!(
                w,
                "-- {macs}x{macs}: worst unhidden stall {:.2}% of latency ({})",
                worst.metric("stall_frac_of_latency") * 100.0,
                worst.point.variant.map_or("?", engine::variant_label)
            )?;
        }
    }
    Ok(rows)
}

/// Monte-Carlo PT analysis (Figs. 7–8) through the sweep engine: one row
/// per (tech × Δ × samples) point, default 20 k samples on the STT bases.
pub fn montecarlo(w: &mut impl Write) -> std::io::Result<Vec<SweepResult>> {
    montecarlo_with(w, &Runner::default(), 0xD1E5, 20_000)
}

pub fn montecarlo_with(
    w: &mut impl Write,
    r: &Runner,
    seed: u64,
    samples: u64,
) -> std::io::Result<Vec<SweepResult>> {
    // All `--parallel N` workers go to chunk-level parallelism inside each
    // point; points run serially at the outer level so the machine is never
    // oversubscribed (a point's chunks already saturate the pool). Results
    // are bit-identical for any split of the two levels.
    let inner = ThreadPool::new(r.workers());
    let spec = r.resolve(engine::spec_montecarlo(seed, samples, inner));
    // A clean error beats a worker panic for techs without a PT MC model
    // (`--tech sot|sram` parses fine everywhere else).
    if let Some(Axis::Tech(ts)) = spec.axis("tech") {
        if let Some(bad) = ts.iter().find(|t| !t.id().is_stt()) {
            return Err(std::io::Error::new(
                std::io::ErrorKind::InvalidInput,
                format!(
                    "montecarlo supports the STT base cases only (stt, wei2019), got {:?}",
                    bad.token()
                ),
            ));
        }
    }
    let rows = spec.run(&ThreadPool::new(1));
    writeln!(w, "== Monte-Carlo PT analysis (streaming engine, seed {seed:#06x}) ==")?;
    writeln!(
        w,
        "{:<12} {:>6} {:>9} {:>10} {:>10} {:>10} {:>9} {:>9} {:>18}",
        "tech",
        "dGB",
        "samples",
        "ret-viol",
        "wr-static",
        "wr-adjust",
        "E_st pJ",
        "E_adj pJ",
        "d_eff mean±std"
    )?;
    for rec in &rows {
        writeln!(
            w,
            "{:<12} {:>6} {:>9} {:>9.4}% {:>9.3}% {:>9.4}% {:>9.3} {:>9.3} {:>9.2} ± {:<6.2}",
            rec.point.tech.unwrap().name(),
            rec.point.delta.unwrap_or(0.0),
            rec.point.mc_samples.unwrap_or(samples),
            rec.metric("retention_violations") * 100.0,
            rec.metric("write_violations_static") * 100.0,
            rec.metric("write_violations_adjustable") * 100.0,
            rec.metric("energy_static_j") * 1e12,
            rec.metric("energy_adjustable_j") * 1e12,
            rec.metric("delta_mean"),
            rec.metric("delta_std")
        )?;
    }
    if let Some(worst) = rows.iter().max_by(|a, b| {
        a.metric("write_violations_static").total_cmp(&b.metric("write_violations_static"))
    }) {
        writeln!(
            w,
            "-- static driver worst case {:.2}% WER violations vs {:.4}% PTM-adjusted (Fig. 9's point)",
            worst.metric("write_violations_static") * 100.0,
            worst.metric("write_violations_adjustable") * 100.0
        )?;
    }
    Ok(rows)
}

/// Regenerate every figure (10–19) in order, plus the cross-technology
/// comparison — the `stt-ai figures` hot path and the `benches/hotpath.rs`
/// figure-regeneration entry.
pub fn render_all(w: &mut impl Write, r: &Runner) -> std::io::Result<()> {
    fig10_with(w, r)?;
    writeln!(w)?;
    fig11_with(w, r)?;
    writeln!(w)?;
    fig12_with(w, r)?;
    writeln!(w)?;
    fig13_with(w, r)?;
    writeln!(w)?;
    fig14_with(w, r)?;
    writeln!(w)?;
    fig15_with(w, r)?;
    writeln!(w)?;
    fig16_with(w, r)?;
    writeln!(w)?;
    fig17_with(w, r)?;
    writeln!(w)?;
    fig18_with(w, r)?;
    writeln!(w)?;
    fig19_with(w, r)?;
    writeln!(w)?;
    techcmp_with(w, r)?;
    writeln!(w)?;
    stall_with(w, r)?;
    writeln!(w)?;
    Ok(())
}
