//! Figure/table renderers: each function prints the same rows/series the
//! paper reports, consuming the unified `dse::engine` sweep records. Used by
//! the CLI (`stt-ai figures`) and by the benches. [`legacy`] keeps the
//! frozen pre-refactor serial renderers as the golden parity reference.

pub mod export;
pub mod figures;
pub mod legacy;
pub mod table3;

pub use export::{export_all, export_json};
pub use figures::*;
pub use table3::{AcceleratorSummary, CoreCosts, table3_rows};
