//! Figure/table renderers: each function prints the same rows/series the
//! paper reports, consuming the `dse` sweep outputs. Used by the CLI
//! (`stt-ai figures`) and by the criterion benches.

pub mod export;
pub mod figures;
pub mod table3;

pub use export::export_all;
pub use figures::*;
pub use table3::{AcceleratorSummary, CoreCosts, table3_rows};
