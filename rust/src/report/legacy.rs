//! FROZEN pre-refactor serial figure renderers — the golden reference.
//!
//! These are the bespoke per-figure loops the `dse::engine` refactor
//! replaced, kept verbatim so `tests/figures.rs` can assert that the
//! engine-driven renderers in [`super::figures`] produce **byte-identical**
//! text. Do not "improve" this module: its value is that it does not change.
//! Everything here runs strictly serially.

use std::io::Write;

use crate::accel::ArrayConfig;
use crate::dse::{
    capacity::{self, CapacityRow, DramOverheadRow},
    delta::{paper_design_points, DeltaSweep},
    energy_area,
    retention,
    scratchpad::{PartialOfmapRow, ScratchpadEnergyRow},
};
use crate::memsys::DramModel;
use crate::models::{self, DType, Model};
use crate::mram::MtjTech;
use crate::util::units::{fmt_bytes, fmt_time, KB, MB};

fn zoo() -> Vec<Model> {
    models::zoo()
}

/// Fig. 10: model sizes + conv fmap/weight ranges.
pub fn fig10(w: &mut impl Write) -> std::io::Result<Vec<CapacityRow>> {
    writeln!(w, "== Fig. 10: model sizes and conv fmap/weight ranges ==")?;
    writeln!(
        w,
        "{:<14} {:>10} {:>10} {:>12} {:>12} {:>12} {:>12}",
        "model", "int8", "bf16", "fmap-min", "fmap-max", "wt-min", "wt-max"
    )?;
    let rows: Vec<CapacityRow> =
        zoo().iter().map(|m| CapacityRow::analyze(m, DType::Bf16, &[1])).collect();
    for r in &rows {
        writeln!(
            w,
            "{:<14} {:>10} {:>10} {:>12} {:>12} {:>12} {:>12}",
            r.model,
            fmt_bytes(r.size_int8),
            fmt_bytes(r.size_bf16),
            r.fmap_min,
            r.fmap_max,
            r.weight_min,
            r.weight_max
        )?;
    }
    let total: u64 = rows.iter().map(|r| r.size_bf16).sum();
    writeln!(w, "-- zoo total bf16 {} (paper: ~280 MB NVM for bf16 class)", fmt_bytes(total))?;
    Ok(rows)
}

/// Fig. 11: required GLB capacity vs batch size.
pub fn fig11(w: &mut impl Write) -> std::io::Result<Vec<(String, Vec<(u64, u64)>)>> {
    let batches = [1u64, 2, 4, 8];
    writeln!(w, "== Fig. 11: required GLB capacity (int8 | bf16) vs batch ==")?;
    writeln!(w, "{:<14} {}", "model", "batch: 1 | 2 | 4 | 8  (int8, bf16)")?;
    let mut out = Vec::new();
    for m in zoo() {
        let mut series = Vec::new();
        let mut line = format!("{:<14}", m.name);
        for &b in &batches {
            let i8 = m.max_conv_working_set(DType::Int8, b);
            let b16 = m.max_conv_working_set(DType::Bf16, b);
            line += &format!(" {:>9}/{:<9}", fmt_bytes(i8), fmt_bytes(b16));
            series.push((b, b16));
        }
        writeln!(w, "{line}")?;
        out.push((m.name.clone(), series));
    }
    for &b in &batches {
        let need = capacity::glb_capacity_for_zoo(&zoo(), DType::Int8, b);
        let served = capacity::models_served(&zoo(), DType::Int8, b, 12 * MB);
        writeln!(w, "-- batch {b}: zoo-max int8 {} ; 12 MB serves {served}/19", fmt_bytes(need))?;
    }
    Ok(out)
}

/// Fig. 12: extra DRAM latency/energy with a 12 MB GLB.
pub fn fig12(w: &mut impl Write) -> std::io::Result<Vec<DramOverheadRow>> {
    let a = ArrayConfig::paper_42x42();
    let dram = DramModel::ddr4_2933_dual();
    let mut rows = Vec::new();
    writeln!(w, "== Fig. 12: extra DRAM access latency/energy (12 MB GLB) ==")?;
    for dt in [DType::Int8, DType::Bf16] {
        writeln!(w, "-- dtype {dt:?}")?;
        writeln!(w, "{:<14} {:>6} {:>12} {:>12} {:>12}", "model", "batch", "spill", "latency", "energy")?;
        for m in zoo() {
            for batch in [1u64, 2, 4, 8] {
                let r = DramOverheadRow::analyze(&m, &a, &dram, dt, batch, 12 * MB);
                if batch == 8 {
                    writeln!(
                        w,
                        "{:<14} {:>6} {:>12} {:>10.3}ms {:>10.3}mJ",
                        r.model,
                        r.batch,
                        fmt_bytes(r.spill_bytes),
                        r.extra_latency * 1e3,
                        r.extra_energy * 1e3
                    )?;
                }
                rows.push(r);
            }
        }
    }
    Ok(rows)
}

/// Fig. 13: GLB retention range per model (42×42 MACs, batch 16, bf16).
pub fn fig13(w: &mut impl Write) -> std::io::Result<Vec<retention::RetentionRow>> {
    writeln!(w, "== Fig. 13: GLB retention time range (42x42 MACs, batch 16) ==")?;
    let rows = retention::fig13(&zoo());
    for r in &rows {
        writeln!(w, "{:<14} min {:>12}  max {:>12}", r.model, fmt_time(r.min_t_ret), fmt_time(r.max_t_ret))?;
    }
    let worst = rows.iter().map(|r| r.max_t_ret).fold(0.0, f64::max);
    writeln!(w, "-- worst case {} (paper: < 1.5 s, most < 0.5 s)", fmt_time(worst))?;
    Ok(rows)
}

/// Fig. 14: max retention vs MAC-array size (a) and batch (b).
pub fn fig14(w: &mut impl Write) -> std::io::Result<(Vec<(u64, f64)>, Vec<(u64, f64)>)> {
    let z = zoo();
    let a = retention::fig14a(&z, &[14, 28, 42, 56, 84]);
    let b = retention::fig14b(&z, &[1, 2, 4, 8, 16, 32]);
    writeln!(w, "== Fig. 14a: max retention vs MAC array (batch 16) ==")?;
    for (macs, t) in &a {
        writeln!(w, "  {macs}x{macs} MACs: {}", fmt_time(*t))?;
    }
    writeln!(w, "== Fig. 14b: max retention vs batch (42x42) ==")?;
    for (batch, t) in &b {
        writeln!(w, "  batch {batch}: {}", fmt_time(*t))?;
    }
    Ok((a, b))
}

/// Fig. 15: Δ scaling panels for both silicon base cases.
pub fn fig15(w: &mut impl Write) -> std::io::Result<Vec<DeltaSweep>> {
    let deltas = DeltaSweep::default_deltas();
    let mut out = Vec::new();
    writeln!(w, "== Fig. 15: thermal-stability scaling ==")?;
    for pts in paper_design_points(MtjTech::sakhare2020()) {
        writeln!(
            w,
            "  {:<22} Δ={:<5.1} Δ_GB={:<5.1} t_w={} t_r={} ret={}",
            pts.label,
            pts.delta_scaled,
            pts.delta_guard_banded,
            fmt_time(pts.write_pulse),
            fmt_time(pts.read_pulse),
            fmt_time(pts.achieved_retention)
        )?;
    }
    for (tech, ber) in [(MtjTech::sakhare2020(), 1e-8), (MtjTech::wei2019(), 1e-8)] {
        let s = DeltaSweep::run(tech, ber, &deltas);
        writeln!(w, "-- base case {} @ BER {ber:.0e}: Δ grid {} points", s.tech, deltas.len())?;
        for d in [12.5, 19.5, 27.5, 39.0, 55.0, 60.0] {
            let i = deltas.iter().position(|&x| (x - d).abs() < 0.6).unwrap_or(0);
            writeln!(
                w,
                "   Δ≈{:<5} retention {:>12}  read {:>10}  write {:>10}",
                d,
                fmt_time(s.retention[i].1),
                fmt_time(s.read_pulse[i].1),
                fmt_time(s.write_pulse[i].1)
            )?;
        }
        out.push(s);
    }
    Ok(out)
}

/// Fig. 16: SRAM vs MRAM energy & area across capacities.
pub fn fig16(w: &mut impl Write) -> std::io::Result<Vec<energy_area::EnergyAreaRow>> {
    writeln!(w, "== Fig. 16: SRAM vs STT-MRAM energy/area vs capacity ==")?;
    let caps = energy_area::default_capacities_mb();
    let mut all = Vec::new();
    for (label, rows) in
        [("GLB Δ_GB=27.5", energy_area::fig16_glb(&caps)), ("LSB Δ_GB=17.5", energy_area::fig16_lsb(&caps))]
    {
        writeln!(w, "-- {label}")?;
        writeln!(w, "{:>6} {:>12} {:>12} {:>8} {:>10} {:>10} {:>8}", "MB", "E_sram", "E_mram", "Ex", "A_sram", "A_mram", "Ax")?;
        for r in &rows {
            writeln!(
                w,
                "{:>6} {:>10.1}pJ {:>10.1}pJ {:>7.2}x {:>8.3}mm2 {:>8.3}mm2 {:>7.1}x",
                r.capacity_bytes / MB,
                r.sram_energy * 1e12,
                r.mram_energy * 1e12,
                r.energy_ratio(),
                r.sram_area,
                r.mram_area,
                r.area_ratio()
            )?;
        }
        all.extend(rows);
    }
    Ok(all)
}

/// Fig. 17: Δ scaling with relaxed BER (LSB bank).
pub fn fig17(w: &mut impl Write) -> std::io::Result<Vec<DeltaSweep>> {
    writeln!(w, "== Fig. 17: Δ scaling at relaxed BER 1e-5 (LSB bank, base [13]) ==")?;
    let deltas = DeltaSweep::default_deltas();
    let relaxed = DeltaSweep::run(MtjTech::wei2019(), 1e-5, &deltas);
    let tight = DeltaSweep::run(MtjTech::wei2019(), 1e-8, &deltas);
    for d in [12.5, 17.5, 27.5] {
        let i = deltas.iter().position(|&x| (x - d).abs() < 0.6).unwrap();
        writeln!(
            w,
            "  Δ≈{:<5} ret {:>10} (vs {:>10} @1e-8)  write {:>10} (vs {:>10})",
            d,
            fmt_time(relaxed.retention[i].1),
            fmt_time(tight.retention[i].1),
            fmt_time(relaxed.write_pulse[i].1),
            fmt_time(tight.write_pulse[i].1)
        )?;
    }
    Ok(vec![relaxed, tight])
}

/// Fig. 18: max partial-ofmap sizes.
pub fn fig18(w: &mut impl Write) -> std::io::Result<Vec<PartialOfmapRow>> {
    writeln!(w, "== Fig. 18: max partial-ofmap size per model ==")?;
    let rows: Vec<PartialOfmapRow> = zoo().iter().map(PartialOfmapRow::analyze).collect();
    let mut fit = 0;
    for r in &rows {
        let ok = r.bf16_bytes <= 52 * KB;
        if ok {
            fit += 1;
        }
        writeln!(
            w,
            "{:<14} bf16 {:>10}  int8 {:>10}  {}",
            r.model,
            fmt_bytes(r.bf16_bytes),
            fmt_bytes(r.int8_bytes),
            if ok { "fits 52 KB" } else { "exceeds 52 KB" }
        )?;
    }
    writeln!(w, "-- {fit}/19 fit the 52 KB bf16 scratchpad (26 KB int8)")?;
    Ok(rows)
}

/// Fig. 19: buffer energy SRAM / MRAM / MRAM+scratchpad (ResNet-50).
pub fn fig19(w: &mut impl Write) -> std::io::Result<ScratchpadEnergyRow> {
    let a = ArrayConfig::paper_42x42();
    let m = models::by_name("ResNet50").unwrap();
    let r = ScratchpadEnergyRow::analyze(&m, &a, DType::Bf16, 16);
    writeln!(w, "== Fig. 19: buffer energy per inference batch (ResNet-50, batch 16) ==")?;
    let base = r.sram.total();
    for (label, l) in
        [("SRAM", &r.sram), ("MRAM", &r.mram), ("MRAM+scratchpad", &r.mram_scratchpad)]
    {
        writeln!(
            w,
            "  {:<16} total {:>10.3} mJ (norm {:.3})  [rd {:.3} wr {:.3} sp {:.3} dram {:.3} mJ]",
            label,
            l.total() * 1e3,
            l.total() / base,
            l.glb_read * 1e3,
            l.glb_write * 1e3,
            l.scratchpad * 1e3,
            l.dram * 1e3
        )?;
    }
    Ok(r)
}
