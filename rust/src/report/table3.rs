//! Table III: accelerator design details at 14 nm — module rows and the
//! three composed accelerators, with the paper's headline savings.


use crate::config::{GlbVariant, SystemConfig};
use crate::memsys::BufferSystem;

/// Post-layout costs of the functional core (Table III row 1 — a synthesis
/// anchor from the paper's Synopsys 14 nm run; see DESIGN.md §3 on why this
/// is a calibration input rather than something we re-synthesize).
#[derive(Debug, Clone, Copy)]
pub struct CoreCosts {
    pub area_mm2: f64,
    pub dynamic_mw: f64,
    pub leakage_mw: f64,
}

impl CoreCosts {
    /// Reconfigurable core with 42×42 MACs (Table III row 1).
    pub fn paper_42x42() -> Self {
        Self { area_mm2: 4.08, dynamic_mw: 954.0, leakage_mw: 0.91 }
    }

    /// First-order rescale of the synthesized anchor to another MAC-array
    /// side (the selection grid's `macs` axis): MAC count — quadratic in the
    /// side — dominates core area and dynamic power, and leakage tracks
    /// area. `for_mac_array(42)` is bit-identical to the anchor.
    pub fn for_mac_array(macs: u64) -> Self {
        let r = (macs as f64 / 42.0).powi(2);
        let base = Self::paper_42x42();
        Self {
            area_mm2: base.area_mm2 * r,
            dynamic_mw: base.dynamic_mw * r,
            leakage_mw: base.leakage_mw * r,
        }
    }
}

/// One composed accelerator (Table III rows 7–9).
#[derive(Debug, Clone)]
pub struct AcceleratorSummary {
    pub name: String,
    pub area_mm2: f64,
    pub dynamic_mw: f64,
    pub leakage_mw: f64,
}

impl AcceleratorSummary {
    pub fn compose(name: &str, core: CoreCosts, buffers: &BufferSystem) -> Self {
        // Scratchpad dynamic power: small and duty-cycled (Table III: 0.2 mW);
        // modeled as a fixed small adder when present.
        let sp_dyn = if buffers.scratchpad.is_some() { 0.2 } else { 0.0 };
        Self {
            name: name.to_string(),
            area_mm2: core.area_mm2 + buffers.area_mm2(),
            dynamic_mw: core.dynamic_mw + buffers.dynamic_power_mw() + sp_dyn,
            leakage_mw: core.leakage_mw + buffers.leakage_mw(),
        }
    }

    pub fn total_power_mw(&self) -> f64 {
        self.dynamic_mw + self.leakage_mw
    }

    /// Fractional saving of `self` vs `baseline` in area / total power.
    pub fn savings_vs(&self, baseline: &AcceleratorSummary) -> (f64, f64) {
        (
            1.0 - self.area_mm2 / baseline.area_mm2,
            1.0 - self.total_power_mw() / baseline.total_power_mw(),
        )
    }
}

/// Build the three Table III accelerator rows from the paper configs.
pub fn table3_rows() -> Vec<AcceleratorSummary> {
    let core = CoreCosts::paper_42x42();
    [
        SystemConfig::paper_baseline(),
        SystemConfig::paper_stt_ai(),
        SystemConfig::paper_stt_ai_ultra(),
    ]
    .iter()
    .map(|cfg| {
        let label = match cfg.glb {
            GlbVariant::Sram => "Baseline (SRAM)",
            GlbVariant::SttAi => "STT-AI",
            GlbVariant::SttAiUltra => "STT-AI Ultra",
        };
        AcceleratorSummary::compose(label, core, &cfg.buffer_system())
    })
    .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn headline_savings_match_paper() {
        // Paper abstract: STT-AI saves 75% area and 3% power; Ultra 75.4%
        // and 3.5%. Allow modest tolerance on the composed model.
        let rows = table3_rows();
        let (base, ai, ultra) = (&rows[0], &rows[1], &rows[2]);
        let (a_ai, p_ai) = ai.savings_vs(base);
        assert!((a_ai - 0.75).abs() < 0.03, "STT-AI area saving {a_ai}");
        assert!((p_ai - 0.03).abs() < 0.015, "STT-AI power saving {p_ai}");
        let (a_u, p_u) = ultra.savings_vs(base);
        assert!(a_u > a_ai, "Ultra must save more area");
        assert!(p_u > p_ai, "Ultra must save more power");
        assert!((a_u - 0.754).abs() < 0.03, "Ultra area saving {a_u}");
    }

    #[test]
    fn absolute_numbers_near_table3() {
        let rows = table3_rows();
        // Baseline 20.28 mm², 1003 mW dynamic class.
        assert!((rows[0].area_mm2 - 20.28).abs() / 20.28 < 0.03, "{}", rows[0].area_mm2);
        assert!((rows[0].dynamic_mw - 1003.0).abs() / 1003.0 < 0.05, "{}", rows[0].dynamic_mw);
        // STT-AI ≈ 5.09 mm².
        assert!((rows[1].area_mm2 - 5.09).abs() / 5.09 < 0.05, "{}", rows[1].area_mm2);
        // Ultra ≈ 5.0 mm².
        assert!((rows[2].area_mm2 - 5.0).abs() / 5.0 < 0.05, "{}", rows[2].area_mm2);
    }

    #[test]
    fn core_rescale_anchors_at_the_paper_array() {
        let anchor = CoreCosts::paper_42x42();
        let same = CoreCosts::for_mac_array(42);
        assert_eq!(same.area_mm2, anchor.area_mm2);
        assert_eq!(same.dynamic_mw, anchor.dynamic_mw);
        assert_eq!(same.leakage_mw, anchor.leakage_mw);
        // Doubling the side quadruples the MAC count → 4× the core costs.
        let big = CoreCosts::for_mac_array(84);
        assert!((big.area_mm2 / anchor.area_mm2 - 4.0).abs() < 1e-12);
        assert!((big.dynamic_mw / anchor.dynamic_mw - 4.0).abs() < 1e-12);
    }

    #[test]
    fn leakage_ordering() {
        let rows = table3_rows();
        assert!(rows[1].leakage_mw < rows[0].leakage_mw);
        assert!(rows[2].leakage_mw < rows[1].leakage_mw);
    }
}
