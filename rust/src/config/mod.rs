//! Typed configuration for the whole system, with JSON load/save.
//!
//! One [`SystemConfig`] describes an accelerator build the way the paper's
//! §V does: the PE array (Table II), the GLB organization (§V.F variants),
//! the scratchpad, the MRAM technology base case and reliability targets, and
//! the serving/coordinator knobs. `SystemConfig::paper_*` are the three
//! evaluated design points.

use std::path::Path;


use crate::accel::ArrayConfig;
use crate::memsys::{BankSpec, BufferSystem, GlbKind, Scratchpad};
use crate::models::DType;
use crate::mram::technology::{MemTechnology, TechnologyId};
use crate::mram::{DesignTargets, PtVariation};
use crate::util::json::Json;
use crate::util::units::{KB, MB};

/// GLB variant selector (serializable mirror of [`GlbKind`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum GlbVariant {
    /// 12 MB SRAM (Baseline).
    Sram,
    /// 12 MB MRAM Δ_PT_GB = 27.5 (STT-AI).
    SttAi,
    /// 6+6 MB MRAM 27.5/17.5 MSB/LSB banks (STT-AI Ultra).
    SttAiUltra,
}

impl GlbVariant {
    /// The GLB organization with the default (paper STT) technology.
    pub fn kind(&self) -> GlbKind {
        self.kind_for(&TechConfig::default())
    }

    /// The GLB organization built in a specific technology: the variant
    /// picks the bank *structure* (mono vs MSB/LSB split), the technology
    /// picks the cells. A volatile technology collapses both MRAM variants
    /// to the single-bank baseline (no Δ knob to split on).
    pub fn kind_for(&self, tech: &TechConfig) -> GlbKind {
        let id = tech.base.id();
        if matches!(self, GlbVariant::Sram) || id == TechnologyId::Sram {
            return GlbKind::baseline();
        }
        let glb = BankSpec::new(id, tech.glb_delta());
        match self {
            GlbVariant::Sram => unreachable!("handled above"),
            GlbVariant::SttAi => GlbKind::Mono(glb),
            GlbVariant::SttAiUltra => {
                GlbKind::Split { msb: glb, lsb: BankSpec::new(id, tech.lsb_delta()) }
            }
        }
    }

    /// Parse a CLI token — the one grammar shared by `stt-ai serve --variant`
    /// and the sweep engine's `variant=` axis.
    pub fn from_token(s: &str) -> Option<Self> {
        match s.to_lowercase().replace('-', "_").as_str() {
            "sram" | "baseline" => Some(GlbVariant::Sram),
            "stt_ai" | "sttai" => Some(GlbVariant::SttAi),
            "stt_ai_ultra" | "ultra" => Some(GlbVariant::SttAiUltra),
            _ => None,
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            GlbVariant::Sram => "Baseline (SRAM)",
            GlbVariant::SttAi => "STT-AI",
            GlbVariant::SttAiUltra => "STT-AI Ultra",
        }
    }
}

/// Memory-technology selector: one entry per registered
/// [`MemTechnology`] base case (serializable mirror of [`TechnologyId`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum TechBase {
    /// STT-MRAM, Sakhare et al. 2020 [6].
    #[default]
    Sakhare2020,
    /// STT-MRAM, Wei et al. 2019 [13].
    Wei2019,
    /// SOT-MRAM (ROADMAP co-optimization scenario).
    Sot,
    /// Volatile SRAM baseline.
    Sram,
}

impl TechBase {
    pub fn id(&self) -> TechnologyId {
        match self {
            TechBase::Sakhare2020 => TechnologyId::SttSakhare2020,
            TechBase::Wei2019 => TechnologyId::SttWei2019,
            TechBase::Sot => TechnologyId::Sot,
            TechBase::Sram => TechnologyId::Sram,
        }
    }

    /// The technology model behind this selector.
    pub fn technology(&self) -> &'static dyn MemTechnology {
        self.id().technology()
    }

    /// Stable base-case name (the sweep-record `tech` column).
    pub fn name(&self) -> &'static str {
        self.technology().name()
    }

    /// Canonical serialization token.
    pub fn token(&self) -> &'static str {
        match self {
            TechBase::Sakhare2020 => "sakhare2020",
            TechBase::Wei2019 => "wei2019",
            TechBase::Sot => "sot",
            TechBase::Sram => "sram",
        }
    }

    /// Every registered base case, in registry order (the default grid of a
    /// cross-technology sweep).
    pub fn all() -> [TechBase; 4] {
        [TechBase::Sakhare2020, TechBase::Wei2019, TechBase::Sot, TechBase::Sram]
    }

    /// The selector for a registry id.
    pub fn from_id(id: TechnologyId) -> Self {
        match id {
            TechnologyId::SttSakhare2020 => TechBase::Sakhare2020,
            TechnologyId::SttWei2019 => TechBase::Wei2019,
            TechnologyId::Sot => TechBase::Sot,
            TechnologyId::Sram => TechBase::Sram,
        }
    }

    /// Parse a CLI token: family tokens (`stt` / `sot` / `sram`) or explicit
    /// base-case names (`sakhare2020` / `wei2019` / `sot2023`). One grammar,
    /// owned by the registry ([`crate::mram::technology::by_token`]).
    pub fn from_token(s: &str) -> Option<Self> {
        crate::mram::technology::by_token(s).map(|t| Self::from_id(t.id()))
    }
}

/// The `[tech.*]` configuration section: which registered technology the
/// accelerator's GLB is built in, plus optional Δ design-point overrides.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct TechConfig {
    /// Registered technology base case.
    pub base: TechBase,
    /// Δ_PT_GB override for the (mono or MSB) GLB bank.
    pub glb_delta_override: Option<f64>,
    /// Δ_PT_GB override for the relaxed LSB bank.
    pub lsb_delta_override: Option<f64>,
}

impl TechConfig {
    pub fn new(base: TechBase) -> Self {
        Self { base, ..Self::default() }
    }

    /// Effective GLB-bank Δ (override or the technology default).
    pub fn glb_delta(&self) -> f64 {
        self.glb_delta_override.unwrap_or_else(|| self.base.technology().default_glb_delta())
    }

    /// Effective LSB-bank Δ (override or the technology default).
    pub fn lsb_delta(&self) -> f64 {
        self.lsb_delta_override.unwrap_or_else(|| self.base.technology().default_lsb_delta())
    }
}

/// The `[deployment]` configuration section: what this deployment optimizes
/// and must not violate. `stt-ai select` evaluates it over the selection
/// sweep ([`crate::dse::select`]) to derive the design point the serving
/// coordinator boots from, replacing the hard-coded paper variants.
#[derive(Debug, Clone, PartialEq)]
pub struct DeploymentConfig {
    /// What the deployment optimizes.
    pub objective: crate::dse::select::Objective,
    /// Iso-accuracy floor (normalized estimated accuracy).
    pub min_accuracy: Option<f64>,
    /// Require worst-bank retention to cover the workload occupancy (the
    /// §V.C design rule).
    pub retention_covers_occupancy: bool,
    /// Optional accelerator area budget (mm²).
    pub max_area_mm2: Option<f64>,
    /// Optional accelerator total-power budget (mW).
    pub max_power_mw: Option<f64>,
    /// Optional GLB-capacity grid (MiB) for the selection sweep — reshapes
    /// the `glb_mb` axis of the candidate grid when set.
    pub glb_mb: Option<Vec<u64>>,
    /// Optional MAC-array-side grid for the selection sweep — reshapes the
    /// `macs` axis of the candidate grid when set.
    pub macs: Option<Vec<u64>>,
    /// Which candidate grid the selection sweep starts from (the 108-point
    /// default or the 2592-point dense stress grid); explicit `glb_mb` /
    /// `macs` knobs and CLI `--sweep` overrides still reshape its axes.
    pub grid: crate::dse::select::SelectionGrid,
}

impl Default for DeploymentConfig {
    /// The paper's deployment: minimum area at "<1 % normalized drop" with
    /// retention covering occupancy, on the default candidate grid.
    fn default() -> Self {
        Self {
            objective: crate::dse::select::Objective::MinArea,
            min_accuracy: Some(0.99),
            retention_covers_occupancy: true,
            max_area_mm2: None,
            max_power_mw: None,
            glb_mb: None,
            macs: None,
            grid: crate::dse::select::SelectionGrid::Default,
        }
    }
}

impl DeploymentConfig {
    /// The constraint set this section implies.
    pub fn constraints(&self) -> Vec<crate::dse::select::Constraint> {
        use crate::dse::select::Constraint;
        let mut cs = Vec::new();
        if let Some(floor) = self.min_accuracy {
            cs.push(Constraint::MinAccuracy(floor));
        }
        if self.retention_covers_occupancy {
            cs.push(Constraint::RetentionCoversOccupancy);
        }
        if let Some(cap) = self.max_area_mm2 {
            cs.push(Constraint::MaxAreaMm2(cap));
        }
        if let Some(cap) = self.max_power_mw {
            cs.push(Constraint::MaxPowerMw(cap));
        }
        cs
    }

    /// Axis overrides implied by the grid knobs: a set `glb_mb`/`macs` list
    /// reshapes the matching axis of the selection candidate grid (same
    /// mechanism as a CLI `--sweep glb_mb=...` override).
    pub fn grid_overrides(&self) -> Vec<crate::dse::engine::Axis> {
        let mut over = Vec::new();
        if let Some(g) = &self.glb_mb {
            over.push(crate::dse::engine::Axis::GlbMb(g.clone()));
        }
        if let Some(m) = &self.macs {
            over.push(crate::dse::engine::Axis::Macs(m.clone()));
        }
        over
    }

    fn to_json(&self) -> Json {
        let mut fields =
            vec![("objective", Json::Str(self.objective.token().to_string()))];
        if let Some(f) = self.min_accuracy {
            fields.push(("min_accuracy", Json::Num(f)));
        }
        fields.push(("retention_covers_occupancy", self.retention_covers_occupancy.into()));
        if let Some(c) = self.max_area_mm2 {
            fields.push(("max_area_mm2", Json::Num(c)));
        }
        if let Some(c) = self.max_power_mw {
            fields.push(("max_power_mw", Json::Num(c)));
        }
        if let Some(g) = &self.glb_mb {
            fields.push(("glb_mb", Json::Arr(g.iter().map(|v| (*v).into()).collect())));
        }
        if let Some(m) = &self.macs {
            fields.push(("macs", Json::Arr(m.iter().map(|v| (*v).into()).collect())));
        }
        // Emitted only off-default so records written before the knob
        // existed stay byte-identical on a round trip.
        if self.grid != crate::dse::select::SelectionGrid::Default {
            fields.push(("grid", Json::Str(self.grid.token().to_string())));
        }
        Json::obj(fields)
    }

    fn from_json(j: &Json) -> crate::Result<Self> {
        use anyhow::Context;
        let mut cfg = Self::default();
        let token = j.req_str("objective").map_err(anyhow::Error::from)?;
        cfg.objective = crate::dse::select::Objective::from_token(token)
            .ok_or_else(|| anyhow::anyhow!("unknown objective {token:?}"))?;
        cfg.min_accuracy = match j.get("min_accuracy") {
            Some(v) => Some(v.as_f64().context("min_accuracy")?),
            None => None,
        };
        if let Some(v) = j.get("retention_covers_occupancy") {
            cfg.retention_covers_occupancy =
                v.as_bool().context("retention_covers_occupancy")?;
        }
        cfg.max_area_mm2 = match j.get("max_area_mm2") {
            Some(v) => Some(v.as_f64().context("max_area_mm2")?),
            None => None,
        };
        cfg.max_power_mw = match j.get("max_power_mw") {
            Some(v) => Some(v.as_f64().context("max_power_mw")?),
            None => None,
        };
        cfg.glb_mb = match j.get("glb_mb") {
            Some(v) => Some(parse_u64_grid(v, "glb_mb")?),
            None => None,
        };
        cfg.macs = match j.get("macs") {
            Some(v) => Some(parse_u64_grid(v, "macs")?),
            None => None,
        };
        if let Some(v) = j.get("grid") {
            let token = v.as_str().ok_or_else(|| anyhow::anyhow!("grid must be a string"))?;
            cfg.grid = crate::dse::select::SelectionGrid::from_token(token)
                .ok_or_else(|| anyhow::anyhow!("unknown selection grid {token:?}"))?;
        }
        Ok(cfg)
    }
}

/// Parse a non-empty JSON array of positive integers (the deployment grid
/// knobs).
fn parse_u64_grid(v: &Json, what: &str) -> crate::Result<Vec<u64>> {
    let arr = v
        .as_arr()
        .ok_or_else(|| anyhow::anyhow!("{what} must be an array of integers"))?;
    let grid: Vec<u64> = arr
        .iter()
        .map(|x| {
            x.as_u64()
                .filter(|n| *n > 0)
                .ok_or_else(|| anyhow::anyhow!("{what} entries must be positive integers"))
        })
        .collect::<crate::Result<_>>()?;
    if grid.is_empty() {
        anyhow::bail!("{what} grid must not be empty");
    }
    Ok(grid)
}

/// Serving-side knobs for the coordinator.
#[derive(Debug, Clone)]
pub struct ServingConfig {
    /// Maximum dynamic-batch size.
    pub max_batch: usize,
    /// Batching window (us): how long the batcher waits to fill a batch.
    pub batch_window_us: u64,
    /// Request-queue depth before backpressure.
    pub queue_depth: usize,
}

impl Default for ServingConfig {
    fn default() -> Self {
        Self { max_batch: 16, batch_window_us: 500, queue_depth: 1024 }
    }
}

/// Fault-injection (BER) settings for the three variants.
#[derive(Debug, Clone, Copy)]
pub struct BerConfig {
    /// BER of the robust (MSB-group) bank.
    pub msb_ber: f64,
    /// BER of the relaxed (LSB-group) bank.
    pub lsb_ber: f64,
    /// RNG seed for reproducible injection.
    pub seed: u64,
}

impl BerConfig {
    pub fn for_variant(v: GlbVariant) -> Self {
        match v {
            // SRAM: no MRAM-induced flips.
            GlbVariant::Sram => Self { msb_ber: 0.0, lsb_ber: 0.0, seed: 0xC0FFEE },
            // STT-AI: 1e-8 across all bits (single robust bank).
            GlbVariant::SttAi => Self { msb_ber: 1e-8, lsb_ber: 1e-8, seed: 0xC0FFEE },
            // Ultra: MSB groups at 1e-8, LSB groups at 1e-5.
            GlbVariant::SttAiUltra => Self { msb_ber: 1e-8, lsb_ber: 1e-5, seed: 0xC0FFEE },
        }
    }

    /// The budget implied by a selected design point: the variant picks the
    /// bank *structure*, the selection's (optional) robust-bank BER budget
    /// replaces the paper default. The Ultra split keeps the paper's
    /// three-decade MSB→LSB relaxation; SRAM never flips bits.
    pub fn for_selection(v: GlbVariant, msb_ber: Option<f64>) -> Self {
        let mut c = Self::for_variant(v);
        if let Some(b) = msb_ber {
            match v {
                GlbVariant::Sram => {}
                GlbVariant::SttAi => {
                    c.msb_ber = b;
                    c.lsb_ber = b;
                }
                GlbVariant::SttAiUltra => {
                    c.msb_ber = b;
                    c.lsb_ber = (b * 1.0e3).min(0.5);
                }
            }
        }
        c
    }
}

/// The full system description.
#[derive(Debug, Clone)]
pub struct SystemConfig {
    /// Human-readable name of this build.
    pub name: String,
    /// GLB variant.
    pub glb: GlbVariant,
    /// GLB capacity in bytes (paper: 12 MB).
    pub glb_bytes: u64,
    /// Scratchpad capacity in bytes (paper: 52 KB bf16 / 26 KB int8);
    /// 0 disables the scratchpad.
    pub scratchpad_bytes: u64,
    /// Datatype of the hardware build.
    pub dtype: DTypeConfig,
    /// PE-array geometry + Table II timing.
    pub array: ArrayConfig,
    /// Memory-technology section (`[tech.*]`): base case + Δ overrides.
    pub tech: TechConfig,
    /// Serving knobs.
    pub serving: ServingConfig,
    /// Deployment objective/constraint section (`[deployment]`): what
    /// `stt-ai select` optimizes when deriving this build's design point.
    pub deployment: DeploymentConfig,
    /// Optional fault-injection section (`[faults]`): a named, seeded
    /// scenario the chaos harness replays against this build
    /// (`stt-ai serve --faults` / `stt-ai chaos`). Absent by default.
    pub faults: Option<crate::coordinator::faults::FaultSchedule>,
    /// Optional arrival-trace section (`[traffic]`): a named, seeded
    /// open-loop trace the fleet simulator offers against this build
    /// (`stt-ai fleet`, default when `--trace` is not given). Absent by
    /// default.
    pub traffic: Option<crate::coordinator::traffic::ArrivalTrace>,
    /// Optional multi-tenant section (`[tenants]`): the named SLO-class
    /// mix sharing the fleet (`stt-ai fleet`, default when `--tenants` is
    /// not given). Absent by default — a fleet without one runs the
    /// legacy single-tenant stack byte for byte.
    pub tenants: Option<crate::coordinator::tenant::TenantMix>,
}

/// Serializable datatype.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DTypeConfig {
    Int8,
    Bf16,
}

impl DTypeConfig {
    pub fn dtype(&self) -> DType {
        match self {
            DTypeConfig::Int8 => DType::Int8,
            DTypeConfig::Bf16 => DType::Bf16,
        }
    }
}

impl SystemConfig {
    /// Baseline: 12 MB SRAM GLB, no scratchpad.
    pub fn paper_baseline() -> Self {
        Self {
            name: "baseline-sram".into(),
            glb: GlbVariant::Sram,
            glb_bytes: 12 * MB,
            scratchpad_bytes: 0,
            dtype: DTypeConfig::Bf16,
            array: ArrayConfig::paper_42x42(),
            tech: TechConfig::default(),
            serving: ServingConfig::default(),
            deployment: DeploymentConfig::default(),
            faults: None,
            traffic: None,
            tenants: None,
        }
    }

    /// STT-AI: 12 MB MRAM (Δ_PT_GB 27.5) + 52 KB scratchpad.
    pub fn paper_stt_ai() -> Self {
        Self {
            name: "stt-ai".into(),
            glb: GlbVariant::SttAi,
            scratchpad_bytes: 52 * KB,
            ..Self::paper_baseline()
        }
    }

    /// STT-AI Ultra: 6+6 MB two-bank MRAM + 52 KB scratchpad.
    pub fn paper_stt_ai_ultra() -> Self {
        Self {
            name: "stt-ai-ultra".into(),
            glb: GlbVariant::SttAiUltra,
            scratchpad_bytes: 52 * KB,
            ..Self::paper_baseline()
        }
    }

    /// Materialize the buffer system model: the GLB variant's bank structure
    /// built in the configured technology.
    pub fn buffer_system(&self) -> BufferSystem {
        let sp = (self.scratchpad_bytes > 0).then(|| Scratchpad::new(self.scratchpad_bytes));
        BufferSystem::new(self.glb.kind_for(&self.tech), self.glb_bytes, sp)
    }

    /// BER settings implied by the GLB variant.
    pub fn ber(&self) -> BerConfig {
        BerConfig::for_variant(self.glb)
    }

    /// GLB reliability targets (the §V.C design points).
    pub fn glb_targets(&self) -> DesignTargets {
        DesignTargets::global_buffer()
    }

    /// PT variation model.
    pub fn variation(&self) -> PtVariation {
        PtVariation::paper()
    }

    /// Serialize to JSON (the offline build carries its own JSON codec).
    pub fn to_json(&self) -> Json {
        let mut fields = vec![
            ("name", Json::Str(self.name.clone())),
            (
                "glb",
                match self.glb {
                    GlbVariant::Sram => "sram",
                    GlbVariant::SttAi => "stt_ai",
                    GlbVariant::SttAiUltra => "stt_ai_ultra",
                }
                .into(),
            ),
            ("glb_bytes", self.glb_bytes.into()),
            ("scratchpad_bytes", self.scratchpad_bytes.into()),
            ("dtype", if self.dtype == DTypeConfig::Int8 { "int8" } else { "bf16" }.into()),
            (
                "array",
                Json::obj(vec![
                    ("w_a", self.array.w_a.into()),
                    ("h_a", self.array.h_a.into()),
                    ("p_s", self.array.p_s.into()),
                    ("clk_hz", Json::Num(self.array.clk_hz)),
                    ("cyc_per_step_conv", self.array.cyc_per_step_conv.into()),
                    ("cyc_per_step_systolic", self.array.cyc_per_step_systolic.into()),
                    ("t_pool_relu", Json::Num(self.array.t_pool_relu)),
                ]),
            ),
            ("tech", {
                let mut fields = vec![("base", Json::Str(self.tech.base.token().to_string()))];
                if let Some(d) = self.tech.glb_delta_override {
                    fields.push(("glb_delta", Json::Num(d)));
                }
                if let Some(d) = self.tech.lsb_delta_override {
                    fields.push(("lsb_delta", Json::Num(d)));
                }
                Json::obj(fields)
            }),
            (
                "serving",
                Json::obj(vec![
                    ("max_batch", (self.serving.max_batch as u64).into()),
                    ("batch_window_us", self.serving.batch_window_us.into()),
                    ("queue_depth", (self.serving.queue_depth as u64).into()),
                ]),
            ),
            ("deployment", self.deployment.to_json()),
        ];
        if let Some(f) = &self.faults {
            fields.push(("faults", f.to_json()));
        }
        if let Some(t) = &self.traffic {
            fields.push(("traffic", t.to_json()));
        }
        if let Some(m) = &self.tenants {
            fields.push(("tenants", m.to_json()));
        }
        Json::obj(fields)
    }

    /// Deserialize from JSON; missing optional sections fall back to the
    /// paper defaults.
    pub fn from_json(j: &Json) -> crate::Result<Self> {
        use anyhow::Context;
        let mut cfg = Self::paper_baseline();
        cfg.name = j.req_str("name").map_err(anyhow::Error::from)?.to_string();
        cfg.glb = match j.req_str("glb").map_err(anyhow::Error::from)? {
            "sram" => GlbVariant::Sram,
            "stt_ai" => GlbVariant::SttAi,
            "stt_ai_ultra" => GlbVariant::SttAiUltra,
            other => anyhow::bail!("unknown glb variant {other:?}"),
        };
        cfg.glb_bytes = j.req_u64("glb_bytes").map_err(anyhow::Error::from)?;
        cfg.scratchpad_bytes = j.req_u64("scratchpad_bytes").map_err(anyhow::Error::from)?;
        if let Some(d) = j.get("dtype").and_then(|d| d.as_str()) {
            cfg.dtype = if d == "int8" { DTypeConfig::Int8 } else { DTypeConfig::Bf16 };
        }
        if let Some(t) = j.get("tech") {
            // Accept both the legacy string form ("wei2019") and the
            // `[tech.*]` section form ({"base": "sot", "glb_delta": 27.5}).
            let base = match t.as_str() {
                Some(s) => s,
                None => t.req_str("base").map_err(anyhow::Error::from)?,
            };
            cfg.tech.base = TechBase::from_token(base)
                .ok_or_else(|| anyhow::anyhow!("unknown tech base {base:?}"))?;
            if let Some(d) = t.get("glb_delta") {
                cfg.tech.glb_delta_override = Some(d.as_f64().context("glb_delta")?);
            }
            if let Some(d) = t.get("lsb_delta") {
                cfg.tech.lsb_delta_override = Some(d.as_f64().context("lsb_delta")?);
            }
        }
        if let Some(a) = j.get("array") {
            cfg.array.w_a = a.req_u64("w_a").map_err(anyhow::Error::from)?;
            cfg.array.h_a = a.req_u64("h_a").map_err(anyhow::Error::from)?;
            cfg.array.p_s = a.req_u64("p_s").map_err(anyhow::Error::from)?;
            cfg.array.clk_hz =
                a.req("clk_hz").map_err(anyhow::Error::from)?.as_f64().context("clk_hz")?;
            cfg.array.cyc_per_step_conv =
                a.req_u64("cyc_per_step_conv").map_err(anyhow::Error::from)?;
            cfg.array.cyc_per_step_systolic =
                a.req_u64("cyc_per_step_systolic").map_err(anyhow::Error::from)?;
            cfg.array.t_pool_relu =
                a.req("t_pool_relu").map_err(anyhow::Error::from)?.as_f64().context("t_pool_relu")?;
        }
        if let Some(s) = j.get("serving") {
            cfg.serving.max_batch = s.req_u64("max_batch").map_err(anyhow::Error::from)? as usize;
            cfg.serving.batch_window_us =
                s.req_u64("batch_window_us").map_err(anyhow::Error::from)?;
            cfg.serving.queue_depth =
                s.req_u64("queue_depth").map_err(anyhow::Error::from)? as usize;
        }
        if let Some(d) = j.get("deployment") {
            cfg.deployment = DeploymentConfig::from_json(d)?;
        }
        if let Some(f) = j.get("faults") {
            cfg.faults = Some(crate::coordinator::faults::FaultSchedule::from_json(f)?);
        }
        if let Some(t) = j.get("traffic") {
            cfg.traffic = Some(crate::coordinator::traffic::ArrivalTrace::from_json(t)?);
        }
        if let Some(m) = j.get("tenants") {
            cfg.tenants = Some(crate::coordinator::tenant::TenantMix::from_json(m)?);
        }
        Ok(cfg)
    }

    pub fn load(path: &Path) -> crate::Result<Self> {
        let text = std::fs::read_to_string(path)?;
        Self::from_json(&Json::parse(&text).map_err(anyhow::Error::from)?)
    }

    pub fn save(&self, path: &Path) -> crate::Result<()> {
        std::fs::write(path, self.to_json().to_string())?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_variants() {
        let b = SystemConfig::paper_baseline();
        assert_eq!(b.glb_bytes, 12 * MB);
        assert_eq!(b.scratchpad_bytes, 0);
        let s = SystemConfig::paper_stt_ai();
        assert_eq!(s.scratchpad_bytes, 52 * KB);
        let u = SystemConfig::paper_stt_ai_ultra();
        assert_eq!(u.glb, GlbVariant::SttAiUltra);
    }

    #[test]
    fn ber_per_variant() {
        assert_eq!(BerConfig::for_variant(GlbVariant::Sram).msb_ber, 0.0);
        let ultra = BerConfig::for_variant(GlbVariant::SttAiUltra);
        assert_eq!(ultra.msb_ber, 1e-8);
        assert_eq!(ultra.lsb_ber, 1e-5);
    }

    #[test]
    fn json_roundtrip() {
        let c = SystemConfig::paper_stt_ai_ultra();
        let text = c.to_json().to_string();
        let back = SystemConfig::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back.name, c.name);
        assert_eq!(back.glb, c.glb);
        assert_eq!(back.glb_bytes, c.glb_bytes);
        assert_eq!(back.array.w_a, c.array.w_a);
        assert_eq!(back.serving.max_batch, c.serving.max_batch);
    }

    #[test]
    fn faults_section_roundtrips_and_defaults_to_none() {
        // No [faults] section in the paper configs or their serialization.
        let c = SystemConfig::paper_stt_ai_ultra();
        assert!(c.faults.is_none());
        assert!(!c.to_json().to_string().contains("\"faults\""));
        let back = SystemConfig::from_json(&Json::parse(&c.to_json().to_string()).unwrap()).unwrap();
        assert!(back.faults.is_none());
        // With a scenario attached, the section roundtrips exactly.
        let mut c = c;
        c.faults = Some(crate::coordinator::faults::FaultSchedule::builtin("burst_ber").unwrap());
        let text = c.to_json().to_string();
        assert!(text.contains("\"faults\""), "{text}");
        let back = SystemConfig::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back.faults, c.faults);
        assert_eq!(back.to_json().to_string(), text, "byte-stable");
    }

    #[test]
    fn traffic_section_roundtrips_and_defaults_to_none() {
        // No [traffic] section in the paper configs or their serialization.
        let c = SystemConfig::paper_stt_ai_ultra();
        assert!(c.traffic.is_none());
        assert!(!c.to_json().to_string().contains("\"traffic\""));
        let back = SystemConfig::from_json(&Json::parse(&c.to_json().to_string()).unwrap()).unwrap();
        assert!(back.traffic.is_none());
        // With a trace attached, the section roundtrips exactly.
        let mut c = c;
        c.traffic = Some(crate::coordinator::traffic::ArrivalTrace::builtin("bursty").unwrap());
        let text = c.to_json().to_string();
        assert!(text.contains("\"traffic\""), "{text}");
        let back = SystemConfig::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back.traffic, c.traffic);
        assert_eq!(back.to_json().to_string(), text, "byte-stable");
    }

    #[test]
    fn tenants_section_roundtrips_and_defaults_to_none() {
        // No [tenants] section in the paper configs or their serialization.
        let c = SystemConfig::paper_stt_ai_ultra();
        assert!(c.tenants.is_none());
        assert!(!c.to_json().to_string().contains("\"tenants\""));
        let back = SystemConfig::from_json(&Json::parse(&c.to_json().to_string()).unwrap()).unwrap();
        assert!(back.tenants.is_none());
        // With a mix attached, the section roundtrips exactly.
        let mut c = c;
        c.tenants = Some(crate::coordinator::tenant::TenantMix::builtin("two_tier").unwrap());
        let text = c.to_json().to_string();
        assert!(text.contains("\"tenants\""), "{text}");
        let back = SystemConfig::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back.tenants, c.tenants);
        assert_eq!(back.to_json().to_string(), text, "byte-stable");
    }

    #[test]
    fn file_roundtrip() {
        let dir = std::env::temp_dir().join("stt_ai_cfg_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("cfg.json");
        let c = SystemConfig::paper_stt_ai();
        c.save(&p).unwrap();
        let back = SystemConfig::load(&p).unwrap();
        assert_eq!(back.name, "stt-ai");
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn buffer_system_materializes() {
        let sys = SystemConfig::paper_stt_ai().buffer_system();
        assert!(sys.scratchpad.is_some());
        let sys = SystemConfig::paper_baseline().buffer_system();
        assert!(sys.scratchpad.is_none());
    }

    #[test]
    fn tech_tokens_cover_registry() {
        for t in TechBase::all() {
            assert_eq!(TechBase::from_token(t.token()), Some(t));
            assert_eq!(t.technology().id(), t.id());
        }
        assert_eq!(TechBase::from_token("stt"), Some(TechBase::Sakhare2020));
        assert_eq!(TechBase::from_token("SOT-MRAM"), Some(TechBase::Sot));
        assert_eq!(TechBase::from_token("reram"), None);
    }

    #[test]
    fn tech_section_roundtrips_with_overrides() {
        let mut c = SystemConfig::paper_stt_ai();
        c.tech = TechConfig {
            base: TechBase::Sot,
            glb_delta_override: Some(24.0),
            lsb_delta_override: None,
        };
        let back = SystemConfig::from_json(&Json::parse(&c.to_json().to_string()).unwrap()).unwrap();
        assert_eq!(back.tech, c.tech);
        assert_eq!(back.tech.glb_delta(), 24.0);
        assert_eq!(back.tech.lsb_delta(), 17.5, "unset override falls back to tech default");
        // Legacy string form still parses.
        let legacy = r#"{"name":"x","glb":"stt_ai","glb_bytes":1048576,
                         "scratchpad_bytes":0,"tech":"wei2019"}"#;
        let cfg = SystemConfig::from_json(&Json::parse(legacy).unwrap()).unwrap();
        assert_eq!(cfg.tech.base, TechBase::Wei2019);
    }

    #[test]
    fn deployment_section_round_trips() {
        use crate::dse::select::{Constraint, Objective};
        let mut c = SystemConfig::paper_stt_ai_ultra();
        assert_eq!(c.deployment, DeploymentConfig::default());
        c.deployment = DeploymentConfig {
            objective: Objective::MinEnergy,
            min_accuracy: Some(0.995),
            retention_covers_occupancy: true,
            max_area_mm2: Some(6.0),
            max_power_mw: None,
            glb_mb: Some(vec![12, 24]),
            macs: Some(vec![42]),
            grid: crate::dse::select::SelectionGrid::Dense,
        };
        let back =
            SystemConfig::from_json(&Json::parse(&c.to_json().to_string()).unwrap()).unwrap();
        assert_eq!(back.deployment, c.deployment);
        assert_eq!(
            back.deployment.constraints(),
            vec![
                Constraint::MinAccuracy(0.995),
                Constraint::RetentionCoversOccupancy,
                Constraint::MaxAreaMm2(6.0)
            ]
        );
        // Grid knobs surface as axis overrides for the selection sweep.
        let over = back.deployment.grid_overrides();
        assert_eq!(over.len(), 2);
        assert_eq!(over[0], crate::dse::engine::Axis::GlbMb(vec![12, 24]));
        assert_eq!(over[1], crate::dse::engine::Axis::Macs(vec![42]));
        // Malformed grids fail loudly.
        let bad = r#"{"name":"x","glb":"sram","glb_bytes":1,"scratchpad_bytes":0,
                      "deployment":{"objective":"area","glb_mb":[0]}}"#;
        assert!(SystemConfig::from_json(&Json::parse(bad).unwrap()).is_err());
        let bad = r#"{"name":"x","glb":"sram","glb_bytes":1,"scratchpad_bytes":0,
                      "deployment":{"objective":"area","macs":[]}}"#;
        assert!(SystemConfig::from_json(&Json::parse(bad).unwrap()).is_err());
        // Unknown grid tokens fail loudly.
        let bad = r#"{"name":"x","glb":"sram","glb_bytes":1,"scratchpad_bytes":0,
                      "deployment":{"objective":"area","grid":"sparse"}}"#;
        assert!(SystemConfig::from_json(&Json::parse(bad).unwrap()).is_err());
        // A config without the section falls back to the paper deployment.
        let legacy = r#"{"name":"x","glb":"stt_ai","glb_bytes":1048576,"scratchpad_bytes":0}"#;
        let cfg = SystemConfig::from_json(&Json::parse(legacy).unwrap()).unwrap();
        assert_eq!(cfg.deployment, DeploymentConfig::default());
        // The default grid is not serialized, so pre-knob records stay
        // byte-stable; omitted grid reads back as Default.
        assert!(!SystemConfig::paper_stt_ai_ultra().to_json().to_string().contains("\"grid\""));
        // Unknown objectives fail loudly.
        let bad = r#"{"name":"x","glb":"sram","glb_bytes":1,"scratchpad_bytes":0,
                      "deployment":{"objective":"vibes"}}"#;
        assert!(SystemConfig::from_json(&Json::parse(bad).unwrap()).is_err());
    }

    #[test]
    fn ber_for_selection_applies_budget_over_variant_structure() {
        // No budget: identical to the paper defaults.
        for v in [GlbVariant::Sram, GlbVariant::SttAi, GlbVariant::SttAiUltra] {
            let (a, b) = (BerConfig::for_selection(v, None), BerConfig::for_variant(v));
            assert_eq!((a.msb_ber, a.lsb_ber, a.seed), (b.msb_ber, b.lsb_ber, b.seed));
        }
        // Mono bank: the budget applies uniformly.
        let c = BerConfig::for_selection(GlbVariant::SttAi, Some(1e-6));
        assert_eq!((c.msb_ber, c.lsb_ber), (1e-6, 1e-6));
        // Ultra: three-decade MSB→LSB relaxation, capped below certainty.
        let c = BerConfig::for_selection(GlbVariant::SttAiUltra, Some(1e-8));
        assert_eq!((c.msb_ber, c.lsb_ber), (1e-8, 1e-5));
        let c = BerConfig::for_selection(GlbVariant::SttAiUltra, Some(1e-2));
        assert_eq!((c.msb_ber, c.lsb_ber), (1e-2, 0.5));
        // SRAM never flips bits, whatever the budget says.
        let c = BerConfig::for_selection(GlbVariant::Sram, Some(1e-3));
        assert_eq!((c.msb_ber, c.lsb_ber), (0.0, 0.0));
    }

    #[test]
    fn variant_structure_composes_with_any_technology() {
        use crate::memsys::GlbKind;
        // Default tech reproduces the paper kinds exactly.
        assert_eq!(GlbVariant::SttAi.kind(), GlbKind::stt_ai());
        assert_eq!(GlbVariant::SttAiUltra.kind(), GlbKind::stt_ai_ultra());
        // SOT keeps the structure, swaps the cells.
        let sot = GlbVariant::SttAiUltra.kind_for(&TechConfig::new(TechBase::Sot));
        match sot {
            GlbKind::Split { msb, lsb } => {
                assert_eq!(msb.tech, TechnologyId::Sot);
                assert_eq!(lsb.tech, TechnologyId::Sot);
                assert!(msb.delta_guard_banded > lsb.delta_guard_banded);
            }
            other => panic!("expected split, got {other:?}"),
        }
        // A volatile technology collapses MRAM variants to the baseline.
        let sram = GlbVariant::SttAiUltra.kind_for(&TechConfig::new(TechBase::Sram));
        assert_eq!(sram, GlbKind::baseline());
    }
}
