//! Integration tests for the streaming Monte-Carlo engine: bit-identical
//! determinism across worker counts *and* chunk sizes, batched-RNG
//! distribution agreement, and the sweep-engine / CLI-facing integration.

use stt_ai::dse::engine::{self, Runner};
use stt_ai::mram::montecarlo::{BLOCK_SAMPLES, DEFAULT_CHUNK_SAMPLES};
use stt_ai::mram::{McResult, MonteCarlo};
use stt_ai::util::pool::ThreadPool;
use stt_ai::util::rng::Rng;
use stt_ai::util::stats::Streaming;

/// Compare every McResult field bit-for-bit (PartialEq would treat -0.0 ==
/// 0.0 and NaN != NaN; the determinism contract is about bits).
fn assert_bits_eq(a: &McResult, b: &McResult, ctx: &str) {
    assert_eq!(a.n, b.n, "{ctx}: n");
    let fields = [
        ("retention_violations", a.retention_violations, b.retention_violations),
        ("write_violations_static", a.write_violations_static, b.write_violations_static),
        (
            "write_violations_adjustable",
            a.write_violations_adjustable,
            b.write_violations_adjustable,
        ),
        ("energy_static", a.energy_static, b.energy_static),
        ("energy_adjustable", a.energy_adjustable, b.energy_adjustable),
        ("delta_mean", a.delta_mean, b.delta_mean),
        ("delta_std", a.delta_std, b.delta_std),
        ("delta_min", a.delta_min, b.delta_min),
        ("delta_max", a.delta_max, b.delta_max),
    ];
    for (name, x, y) in fields {
        assert_eq!(x.to_bits(), y.to_bits(), "{ctx}: {name} ({x} vs {y})");
    }
}

#[test]
fn bit_identical_across_worker_counts_and_chunk_sizes() {
    let mc = MonteCarlo::paper_glb();
    let n = 100_000;
    let reference = mc.run_with(0xD1E5, n, &ThreadPool::new(1), DEFAULT_CHUNK_SAMPLES);
    for workers in [1usize, 2, 8] {
        let pool = ThreadPool::new(workers);
        for chunk in [BLOCK_SAMPLES, 10_000, DEFAULT_CHUNK_SAMPLES, n] {
            let r = mc.run_with(0xD1E5, n, &pool, chunk);
            assert_bits_eq(&reference, &r, &format!("workers={workers} chunk={chunk}"));
        }
    }
    // And a different seed must actually differ (no degenerate constant).
    let other = mc.run_with(0xBEEF, n, &ThreadPool::new(8), DEFAULT_CHUNK_SAMPLES);
    assert_ne!(reference, other);
}

#[test]
fn run_and_run_serial_agree() {
    let mc = MonteCarlo::paper_glb();
    let a = mc.run_serial(0xC0FFEE, 30_000);
    let b = mc.run(0xC0FFEE, 30_000);
    assert_bits_eq(&a, &b, "run vs run_serial");
}

#[test]
fn fill_normal_and_scalar_normal_agree_in_distribution() {
    // 1e6 samples each way: mean within ~5σ/√n, std within the same class.
    let n = 1_000_000usize;
    let mut batched = vec![0.0f64; n];
    Rng::seed_from_u64(0x6042).fill_normal(&mut batched);
    let mut scalar_rng = Rng::seed_from_u64(0x5CA1A7);
    let mut s_batched = Streaming::new();
    let mut s_scalar = Streaming::new();
    for &x in &batched {
        s_batched.push(x);
    }
    for _ in 0..n {
        s_scalar.push(scalar_rng.normal());
    }
    let tol = 5.0 / (n as f64).sqrt();
    assert!(s_batched.mean().abs() < tol, "batched mean {}", s_batched.mean());
    assert!(s_scalar.mean().abs() < tol, "scalar mean {}", s_scalar.mean());
    assert!((s_batched.std_dev() - 1.0).abs() < tol, "batched std {}", s_batched.std_dev());
    assert!((s_scalar.std_dev() - 1.0).abs() < tol, "scalar std {}", s_scalar.std_dev());
    assert!(
        (s_batched.mean() - s_scalar.mean()).abs() < 2.0 * tol
            && (s_batched.std_dev() - s_scalar.std_dev()).abs() < 2.0 * tol,
        "batched and scalar normals must agree in distribution"
    );
}

#[test]
fn montecarlo_sweep_through_runner_matches_direct_engine() {
    // The CLI path (spec through a Runner) and a direct engine run must
    // agree bit-for-bit for the same (tech, Δ, seed, n).
    let n = 8_000u64;
    let spec = engine::spec_montecarlo(0xD1E5, n, ThreadPool::new(1));
    let rows = Runner::new(4).run(spec);
    assert_eq!(rows.len(), 2);
    let stt_row = &rows[0];
    assert_eq!(stt_row.point.tech.unwrap().name(), "sakhare2020");
    let mc = MonteCarlo::paper_glb().at_delta_gb(stt_row.point.delta.unwrap());
    let direct = mc.run_serial(0xD1E5, n as usize);
    assert_eq!(stt_row.metric("retention_violations"), direct.retention_violations);
    assert_eq!(stt_row.metric("energy_adjustable_j"), direct.energy_adjustable);
    assert_eq!(stt_row.metric("delta_std"), direct.delta_std);
}

#[test]
fn non_stt_tech_is_a_clean_error_not_a_panic() {
    use stt_ai::config::TechBase;
    use stt_ai::report::figures;

    // Regression: `montecarlo --tech sot|sram` used to reach the evaluator
    // and abort with a raw worker panic. The CLI renderer must surface a
    // clean error instead.
    for tech in ["sot", "sram"] {
        let runner =
            Runner::new(1).with_overrides(engine::parse_axes(&format!("tech={tech}")).unwrap());
        let mut buf = Vec::new();
        let err = figures::montecarlo_with(&mut buf, &runner, 0xD1E5, 1_000)
            .expect_err("non-STT tech must not render");
        assert!(err.to_string().contains("STT base cases"), "{err}");
    }
    // The Result-returning constructor rejects the grid up front...
    let err = engine::spec_montecarlo_for(
        0xD1E5,
        1_000,
        ThreadPool::new(1),
        vec![TechBase::Sakhare2020, TechBase::Sot],
    )
    .expect_err("SOT has no PT Monte-Carlo model yet")
    .to_string();
    assert!(err.contains("sot"), "{err}");
    // ...while both STT base cases (and the default spec) still build.
    for tech in [TechBase::Sakhare2020, TechBase::Wei2019] {
        let spec =
            engine::spec_montecarlo_for(0xD1E5, 1_000, ThreadPool::new(1), vec![tech]).unwrap();
        assert_eq!(spec.len(), 1);
    }
}
