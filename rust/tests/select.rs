//! Integration: sweep-driven design-point selection reproduces the paper's
//! picks as goldens, is deterministic across worker counts, and boots the
//! serving configuration end-to-end from the selected record — with no
//! hard-coded `GlbVariant` between the sweep and the engine config.

use stt_ai::config::{GlbVariant, TechBase};
use stt_ai::coordinator::EngineConfig;
use stt_ai::dse::engine::{parse_axes, shared_zoo, DesignPoint, Runner, SweepColumns, SweepResult};
use stt_ai::dse::select::{self, Constraint, DesignSelection, Objective, SelectionGrid};
use stt_ai::memsys::GlbKind;
use stt_ai::report::export;
use stt_ai::util::pool::ThreadPool;

fn paper_constraints() -> Vec<Constraint> {
    vec![Constraint::MinAccuracy(0.99), Constraint::RetentionCoversOccupancy]
}

/// The acceptance golden: under an area-minimizing objective at
/// iso-accuracy, the frontier selects the STT-AI Ultra point (≈75.4 % area
/// saving) over SRAM — the paper's Table III headline, derived rather than
/// hard-coded.
#[test]
fn area_objective_at_iso_accuracy_selects_stt_ai_ultra() {
    let zoo = shared_zoo();
    let results = Runner::new(2).run(select::spec_selection(&zoo));
    let sel =
        select::select("selection", &results, Objective::MinArea, &paper_constraints()).unwrap();
    assert_eq!(sel.variant(), GlbVariant::SttAiUltra, "{sel:?}");
    assert_eq!(sel.point.delta, Some(27.5));
    assert_eq!(sel.point.ber, Some(1.0e-8));
    let saving = sel.metric("area_saving_vs_sram").unwrap();
    assert!((saving - 0.754).abs() < 0.03, "paper: 75.4% area saving, got {saving}");
    // SRAM is feasible (perfect accuracy, infinite retention) but loses on
    // area by ~4x — the constraint set does not carry the win, the
    // objective does.
    let sram_area = results
        .iter()
        .find(|r| r.point.variant == Some(GlbVariant::Sram))
        .unwrap()
        .metric("accel_area_mm2");
    assert!(sel.score < sram_area / 3.0, "{} vs {}", sel.score, sram_area);
}

/// The three paper objectives under the write-bandwidth stall model: area
/// and energy stay with the MRAM designs, while the latency objective now
/// honestly prefers the write-fast SRAM baseline at the scaled-up array —
/// the ranking the old variant-invariant compute walk could not express.
#[test]
fn paper_objectives_rank_under_the_stall_model() {
    let zoo = shared_zoo();
    let results = Runner::new(2).run(select::spec_selection(&zoo));
    let selections = select::paper_selections(&results).unwrap();
    assert_eq!(selections.len(), 3);
    for sel in &selections {
        assert!(sel.feasible > 0 && sel.frontier > 0);
        assert!(sel.metric("est_accuracy").unwrap() >= 0.99);
        assert_eq!(sel.latency_model, select::LATENCY_MODEL, "{:?}", sel.objective);
    }
    // Area and energy picks are MRAM designs; the energy pick is the Ultra
    // split (its relaxed LSB bank writes cheaper at the same capacity).
    assert_ne!(selections[0].variant(), GlbVariant::Sram);
    assert_eq!(selections[1].objective, Objective::MinEnergy);
    assert_eq!(selections[1].variant(), GlbVariant::SttAiUltra);
    // The latency pick is the write-bandwidth winner: the SRAM GLB (writes
    // at the practical pulse floor → zero stall) on the 84×84 array with
    // the largest swept GLB (least DRAM spill).
    assert_eq!(selections[2].objective, Objective::MinLatency);
    assert_eq!(selections[2].variant(), GlbVariant::Sram);
    assert_eq!(selections[2].point.macs, Some(84));
    assert_eq!(selections[2].point.glb_mb, Some(24));
    assert_eq!(selections[2].metric("stall_s"), Some(0.0));
    // Among the MRAM candidates the split GLB out-serves the mono bank, so
    // Ultra strictly beats STT-AI on latency at iso coordinates.
    let latency_at = |v: GlbVariant| {
        results
            .iter()
            .find(|r| {
                r.point.variant == Some(v)
                    && r.point.delta == Some(27.5)
                    && r.point.ber == Some(1.0e-8)
                    && r.point.glb_mb == Some(24)
                    && r.point.macs == Some(84)
            })
            .unwrap()
            .metric("latency_s")
    };
    assert!(latency_at(GlbVariant::SttAiUltra) < latency_at(GlbVariant::SttAi));
}

/// Selection is deterministic: worker count must not change the winner or
/// any byte of the serialized record.
#[test]
fn selection_is_worker_count_invariant() {
    let zoo = shared_zoo();
    let spec = select::spec_selection(&zoo);
    let serial = Runner::new(1).run(spec.clone());
    let parallel = Runner::new(8).run(spec);
    assert_eq!(serial, parallel, "candidate records must be byte-stable");
    // The re-derived records (stall-model latency included) are byte-stable
    // for every paper objective, not just the area golden.
    for objective in [Objective::MinArea, Objective::MinLatency, Objective::MaxThroughput] {
        let a = select::select("selection", &serial, objective, &paper_constraints()).unwrap();
        let b = select::select("selection", &parallel, objective, &paper_constraints()).unwrap();
        assert_eq!(a.to_json().to_string(), b.to_json().to_string(), "{objective:?}");
    }
}

/// The columnar hot path (SweepColumns + per-column masks behind `select`)
/// reproduces the committed golden byte for byte at `--parallel 1` and
/// `--parallel 4`: the SoA rewrite may not move a single byte of any
/// selection record, and the record-path mask wrappers must agree with the
/// columnar mask functions on the real candidate grid.
#[test]
fn columnar_selection_reproduces_the_golden_at_both_worker_counts() {
    let zoo = shared_zoo();
    let spec = select::spec_selection(&zoo);
    let constraints = paper_constraints();
    let mut per_worker_jsons: Vec<Vec<String>> = Vec::new();
    for workers in [1usize, 4] {
        let results = Runner::new(workers).run(spec.clone());
        // The SoA view is lossless over the real 108-candidate grid.
        let cols = SweepColumns::from_results(&results);
        assert_eq!(cols.to_results(), results, "workers={workers}");
        // Mask parity: record-path wrappers == columnar functions.
        assert_eq!(
            select::feasible_mask(&results, &constraints),
            select::feasible_mask_columns(&cols, &constraints),
            "workers={workers}"
        );
        assert_eq!(
            select::pareto_mask(&results, &Objective::all()),
            select::pareto_mask_columns(&cols, &Objective::all()),
            "workers={workers}"
        );
        // The committed golden: area objective at iso-accuracy picks the
        // Ultra split at the paper coordinates.
        let sel = select::select("selection", &results, Objective::MinArea, &constraints).unwrap();
        assert_eq!(sel.variant(), GlbVariant::SttAiUltra, "workers={workers}");
        assert_eq!(sel.point.delta, Some(27.5));
        assert_eq!(sel.point.ber, Some(1.0e-8));
        let saving = sel.metric("area_saving_vs_sram").unwrap();
        assert!((saving - 0.754).abs() < 0.03, "workers={workers}: {saving}");
        // Serialized records for every objective, for the cross-worker
        // byte comparison below.
        per_worker_jsons.push(
            Objective::all()
                .iter()
                .map(|&o| {
                    select::select("selection", &results, o, &constraints)
                        .unwrap()
                        .to_json()
                        .to_string()
                })
                .collect(),
        );
    }
    assert_eq!(
        per_worker_jsons[0], per_worker_jsons[1],
        "selection records must be byte-identical at --parallel 1 and 4"
    );
}

/// The full serving bridge: selection record → JSON file → EngineConfig,
/// with the Ultra bank split and the paper BER budget derived end-to-end.
#[test]
fn selection_file_boots_engine_config() {
    let zoo = shared_zoo();
    let results = Runner::new(2).run(select::spec_selection(&zoo));
    let sel =
        select::select("selection", &results, Objective::MinArea, &paper_constraints()).unwrap();

    let dir = std::env::temp_dir().join("stt_ai_select_test");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("selection.json");
    sel.save(&path).unwrap();
    let loaded = DesignSelection::load(&path).unwrap();
    assert_eq!(loaded.point, sel.point);
    assert_eq!(loaded.score, sel.score);

    let config = EngineConfig::from_selection(&loaded);
    assert_eq!(config.variant, GlbVariant::SttAiUltra);
    assert_eq!((config.ber.msb_ber, config.ber.lsb_ber), (1.0e-8, 1.0e-5));
    match loaded.glb_kind() {
        GlbKind::Split { msb, lsb } => {
            assert!(msb.tech.is_stt() && lsb.tech.is_stt());
            assert_eq!((msb.delta_guard_banded, lsb.delta_guard_banded), (27.5, 17.5));
        }
        other => panic!("expected the Ultra split, got {other:?}"),
    }
    // And the selection CSV export round-trips through the report layer.
    let csv_path = dir.join("selection.csv");
    export::write_selection_csv(&csv_path, std::slice::from_ref(&loaded)).unwrap();
    let text = std::fs::read_to_string(&csv_path).unwrap();
    assert_eq!(text.lines().count(), 2);
    assert!(text.lines().nth(1).unwrap().contains("stt_ai_ultra"));
    std::fs::remove_dir_all(&dir).ok();
}

/// CLI-grammar plumbing: `--sweep` overrides reshape the candidate grid,
/// and a selection pins downstream sweeps via its override set.
#[test]
fn sweep_overrides_and_selection_pins_compose() {
    let zoo = shared_zoo();
    // Restrict the grid to the two MRAM variants at the paper budget.
    let runner = Runner::new(2)
        .with_overrides(parse_axes("variant=stt_ai|stt_ai_ultra,ber=1e-8").unwrap());
    let results = runner.run(select::spec_selection(&zoo));
    assert_eq!(results.len(), 2 * 3 * 3 * 2, "2 variants x 3 deltas x 1 ber x 3 glb x 2 macs");
    let sel =
        select::select("selection", &results, Objective::MinArea, &paper_constraints()).unwrap();
    assert_eq!(sel.variant(), GlbVariant::SttAiUltra);
    // The winner's override set collapses a fresh grid to one point.
    let over = select::selection_overrides(&sel.point);
    let pinned = Runner::new(1).with_overrides(over).run(select::spec_selection(&zoo));
    assert_eq!(pinned.len(), 1);
    assert_eq!(pinned[0].point, sel.point);
    assert_eq!(pinned[0].metrics, {
        let m: Vec<(&str, f64)> = sel
            .metrics
            .iter()
            .map(|(k, v)| (k.as_str(), *v))
            .collect();
        m
    });
}

/// A `--from-selection` record naming an unknown model surfaces as a clean
/// load error instead of a worker panic deep in the sweep pool (the old
/// `find_model` unwrap).
#[test]
fn from_selection_with_unknown_model_fails_cleanly() {
    let zoo = shared_zoo();
    let results = Runner::new(2).run(select::spec_selection(&zoo));
    let sel =
        select::select("selection", &results, Objective::MinArea, &paper_constraints()).unwrap();
    let dir = std::env::temp_dir().join("stt_ai_select_badmodel");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("bad.json");
    // Corrupt the record's model name the way a hand-edited file could.
    let text = sel.to_json().to_string().replace("ResNet50", "NotAModel");
    std::fs::write(&path, text).unwrap();
    let err = DesignSelection::load(&path).unwrap_err().to_string();
    assert!(err.contains("unknown model"), "{err}");
    // The pristine record still loads (and validates) fine.
    let good = dir.join("good.json");
    sel.save(&good).unwrap();
    assert!(DesignSelection::load(&good).is_ok());
    std::fs::remove_dir_all(&dir).ok();
}

/// Budget constraints bite: an aggressive area cap rules the SRAM baseline
/// out even without an objective preference, and an impossible cap fails
/// with a clean error.
#[test]
fn budget_constraints_filter_candidates() {
    let zoo = shared_zoo();
    let results = Runner::new(2).run(select::spec_selection(&zoo));
    let sel = select::select(
        "selection",
        &results,
        Objective::MaxThroughput,
        &[Constraint::MaxAreaMm2(10.0)],
    )
    .unwrap();
    assert_ne!(sel.variant(), GlbVariant::Sram, "20 mm2 SRAM cannot meet a 10 mm2 cap");
    let err = select::select(
        "selection",
        &results,
        Objective::MinArea,
        &[Constraint::MaxAreaMm2(0.1)],
    )
    .unwrap_err()
    .to_string();
    assert!(err.contains("no feasible design point"), "{err}");
}

fn rec_with(metrics: Vec<(&'static str, f64)>) -> SweepResult {
    SweepResult { sweep: "mixed".into(), point: DesignPoint::default(), metrics }
}

/// Hole-handling regression (mixed-layout batches): a row whose layout is
/// missing a live objective metric is *excluded* from the frontier — it
/// neither joins it nor sways the dominance ranking of the complete rows —
/// instead of comparing as if the metric were present.
#[test]
fn rows_missing_a_live_objective_metric_are_excluded_from_the_frontier() {
    let objectives = [Objective::MinArea, Objective::MinEnergy];
    let rs = vec![
        rec_with(vec![("accel_area_mm2", 5.0), ("buffer_energy_j", 2.0)]),
        // Missing energy: excluded, even though its area is competitive.
        rec_with(vec![("accel_area_mm2", 4.0)]),
        rec_with(vec![("accel_area_mm2", 6.0), ("buffer_energy_j", 1.0)]),
        // Missing energy with the best area of all: must not dominate the
        // complete rows through the area column.
        rec_with(vec![("accel_area_mm2", 0.1)]),
    ];
    let mask = select::pareto_mask(&rs, &objectives);
    assert_eq!(mask, vec![true, false, true, false]);
    // The columnar view agrees at any pool width.
    let cols = SweepColumns::from_results(&rs);
    for workers in [1usize, 2, 8] {
        assert_eq!(
            select::pareto_mask_columns_with(&cols, &objectives, &ThreadPool::new(workers)),
            mask,
            "workers={workers}"
        );
    }
    // An objective nobody carries stays inert; with no live objective at
    // all the whole batch is trivially non-dominated.
    let none = vec![rec_with(vec![("other", 1.0)]), rec_with(vec![("other", 2.0)])];
    assert_eq!(select::pareto_mask(&none, &[Objective::MinEnergy]), vec![true, true]);
}

/// The `--grid dense` stress grid: 2592 candidates, byte-stable across
/// worker counts, kernel masks matching the scalar folds on real records —
/// and, being a strict superset of the default grid, its area pick can only
/// improve on (or tie) the default one.
#[test]
fn dense_grid_is_deterministic_and_sharpens_the_area_pick() {
    let zoo = shared_zoo();
    assert_eq!(select::spec_selection_grid(&zoo, SelectionGrid::Default).len(), 108);
    let spec = select::spec_selection_grid(&zoo, SelectionGrid::Dense);
    assert_eq!(spec.len(), 2592, "3 variants x 8 deltas x 3 bers x 4 glb x 3 macs");
    let serial = Runner::new(1).run(spec.clone());
    let parallel = Runner::new(4).run(spec);
    assert_eq!(serial, parallel, "dense records must be byte-stable across worker counts");

    // Kernel parity on the real dense grid: the fused feasibility bitmask
    // equals the per-row constraint fold, and the tiled frontier is
    // byte-identical at every pool width.
    let cols = SweepColumns::from_results(&serial);
    let constraints = paper_constraints();
    let folded: Vec<bool> = (0..cols.len())
        .map(|row| constraints.iter().all(|c| c.satisfied_at(&cols, row)))
        .collect();
    assert_eq!(select::feasible_mask_columns(&cols, &constraints), folded);
    let reference = select::pareto_mask_columns_with(&cols, &Objective::all(), &ThreadPool::new(1));
    for workers in [2usize, 8] {
        assert_eq!(
            select::pareto_mask_columns_with(&cols, &Objective::all(), &ThreadPool::new(workers)),
            reference,
            "workers={workers}"
        );
    }

    let dense = select::select("selection", &serial, Objective::MinArea, &constraints).unwrap();
    let default_results = Runner::new(1).run(select::spec_selection(&zoo));
    let base = select::select("selection", &default_results, Objective::MinArea, &constraints)
        .unwrap();
    assert!(
        dense.score <= base.score,
        "superset grid regressed the area pick: dense {} vs default {}",
        dense.score,
        base.score
    );
}

/// The tech axis composes: pinning the Wei 2019 base case still selects an
/// MRAM design under the paper constraints (the registry drives the grid,
/// not hard-coded technology choices).
#[test]
fn selection_composes_with_the_technology_registry() {
    let zoo = shared_zoo();
    let runner = Runner::new(2).with_overrides(parse_axes("tech=wei2019").unwrap());
    let results = runner.run(select::spec_selection(&zoo));
    // The grid itself does not vary tech (no tech axis), so the override is
    // a no-op on the cross-product — but a custom tech axis can be swept by
    // reshaping the spec through `--sweep tech=...` on a spec that varies
    // it. Here we assert the default grid still evaluates under the
    // default (Sakhare 2020) base case.
    assert!(results.iter().all(|r| r.point.tech.is_none()));
    let sel =
        select::select("selection", &results, Objective::MinArea, &paper_constraints()).unwrap();
    assert_eq!(sel.point.tech, None);
    assert_eq!(sel.system_config().tech.base, TechBase::Sakhare2020);
}
