//! Integration tests over the PJRT runtime + coordinator, using the real
//! AOT artifacts. Skipped (with a loud message) if `make artifacts` has not
//! run — keeps `cargo test` usable before the Python build.

use std::path::{Path, PathBuf};

use stt_ai::config::GlbVariant;
use stt_ai::coordinator::{accuracy, serve, Engine, EngineConfig};

fn artifacts() -> Option<PathBuf> {
    let p = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if p.join("manifest.json").exists() {
        Some(p)
    } else {
        eprintln!("SKIP: artifacts/ missing — run `make artifacts`");
        None
    }
}

#[test]
fn manifest_loads_and_is_consistent() {
    let Some(dir) = artifacts() else { return };
    let engine = Engine::load(&dir, EngineConfig::new(GlbVariant::Sram)).unwrap();
    let m = &engine.manifest;
    assert!(m.models.len() >= 2, "expect batch-1 and batch-16 variants");
    for (name, art) in &m.models {
        assert!(m.hlo_path(art).exists(), "{name} HLO missing");
        assert_eq!(art.num_classes, 10);
        assert_eq!(art.input_shape, vec![1, 16, 16]);
    }
    let w = m.load_weights().unwrap();
    let total: u64 = m.models.values().next().unwrap().params.iter().map(|p| p.elems()).sum();
    assert_eq!(w.data.len() as u64, total, "flat weights must cover all params");
    let (imgs, labels) = m.load_testset().unwrap();
    assert_eq!(labels.len(), m.testset.n);
    assert_eq!(imgs.len(), m.testset.n * 256);
}

#[test]
fn inference_is_deterministic_across_engines() {
    let Some(dir) = artifacts() else { return };
    let run = || {
        let engine = Engine::load(&dir, EngineConfig::new(GlbVariant::SttAiUltra)).unwrap();
        let model = engine.model_for_batch(1).unwrap();
        let (images, _) = engine.manifest.load_testset().unwrap();
        engine.infer(&model, &images[..256]).unwrap()
    };
    let a = run();
    let b = run();
    assert_eq!(a, b, "same seed → identical fault pattern → identical logits");
}

#[test]
fn baseline_matches_training_accuracy() {
    let Some(dir) = artifacts() else { return };
    let engine = Engine::load(&dir, EngineConfig::new(GlbVariant::Sram)).unwrap();
    let rep = accuracy::evaluate(&engine, 16, None).unwrap();
    // The bf16 rounding of the fault model costs at most a small amount vs
    // the f32 training accuracy (recorded in the manifest ≈ 0.93).
    assert!(rep.top1 > 0.85, "top1={}", rep.top1);
    assert!(rep.top5 > 0.99, "top5={}", rep.top5);
    assert_eq!(rep.bit_flips, 0, "SRAM variant must not flip bits");
}

#[test]
fn fig21_iso_accuracy_shape() {
    let Some(dir) = artifacts() else { return };
    let row = accuracy::fig21_row(&dir, 0.0, 16, Some(256)).unwrap();
    // Paper: STT-AI (1e-8) iso-accuracy with baseline.
    assert_eq!(row.baseline.top1, row.stt_ai.top1, "1e-8 BER must be iso-accuracy here");
    // Ultra: some flips injected, <1% normalized drop.
    assert!(row.stt_ai_ultra.bit_flips > 0, "Ultra must actually inject flips");
    assert!(row.ultra_drop_normalized() < 0.01, "drop={}", row.ultra_drop_normalized());
}

#[test]
fn pruned_model_still_works() {
    let Some(dir) = artifacts() else { return };
    let engine =
        Engine::load(&dir, EngineConfig::new(GlbVariant::SttAiUltra).with_prune(0.5)).unwrap();
    let rep = accuracy::evaluate(&engine, 16, Some(256)).unwrap();
    assert!(rep.top1 > 0.7, "50%-pruned top1={}", rep.top1);
}

#[test]
fn different_seed_changes_fault_pattern() {
    let Some(dir) = artifacts() else { return };
    let e1 = Engine::load(&dir, EngineConfig::new(GlbVariant::SttAiUltra).with_seed(1)).unwrap();
    let e2 = Engine::load(&dir, EngineConfig::new(GlbVariant::SttAiUltra).with_seed(2)).unwrap();
    assert_ne!(
        e1.served_weights().data,
        e2.served_weights().data,
        "different seeds must corrupt different bits"
    );
}

#[test]
fn activation_faults_injected_and_benign() {
    let Some(dir) = artifacts() else { return };
    let cfg = EngineConfig::new(GlbVariant::SttAiUltra).with_activation_faults();
    let engine = Engine::load(&dir, cfg).unwrap();
    let (images, _) = engine.manifest.load_testset().unwrap();
    // The corrupt path actually changes something at Ultra BERs over a
    // large-enough buffer (512 images × 256 px × 16 bits ≈ 2.1 Mbit; LSB
    // half at 1e-5 ⇒ ~10 expected flips beyond bf16 rounding)…
    let corrupted = engine.corrupt_activations(&images);
    assert_eq!(corrupted.len(), images.len());
    let bf16_only: Vec<f32> =
        images.iter().map(|v| stt_ai::util::bf16::round_via_bf16(*v)).collect();
    assert_ne!(corrupted, bf16_only, "activation faults must land");
    // …and accuracy stays in the paper's band with both weight and
    // activation faults active.
    let rep = accuracy::evaluate(&engine, 16, Some(256)).unwrap();
    assert!(rep.top1 > 0.9, "top1={}", rep.top1);
}

#[test]
fn serve_closed_loop_reports_metrics() {
    let Some(dir) = artifacts() else { return };
    let engine = Engine::load(&dir, EngineConfig::new(GlbVariant::SttAi)).unwrap();
    let summary = serve::closed_loop(&engine, 64, 16).unwrap();
    assert!(summary.contains("served 64 requests"), "{summary}");
    assert!(summary.contains("throughput"), "{summary}");
}

#[test]
fn serve_closed_loop_zero_requests_is_well_formed() {
    // Regression: n_requests = 0 must produce a complete empty summary
    // through the real engine path, not hang or divide by zero.
    let Some(dir) = artifacts() else { return };
    let engine = Engine::load(&dir, EngineConfig::new(GlbVariant::SttAi)).unwrap();
    let summary = serve::closed_loop(&engine, 0, 16).unwrap();
    assert!(summary.starts_with("served 0 requests"), "{summary}");
    assert!(summary.contains("requests=0"), "{summary}");
}

#[test]
fn batch1_and_batch16_agree() {
    let Some(dir) = artifacts() else { return };
    let engine = Engine::load(&dir, EngineConfig::new(GlbVariant::Sram)).unwrap();
    let m1 = engine.model_for_batch(1).unwrap();
    let m16 = engine.model_for_batch(16).unwrap();
    let (images, _) = engine.manifest.load_testset().unwrap();
    let logits16 = engine.infer(&m16, &images[..16 * 256]).unwrap();
    for i in 0..4 {
        let l1 = engine.infer(&m1, &images[i * 256..(i + 1) * 256]).unwrap();
        let l16 = &logits16[i * 10..(i + 1) * 10];
        for (a, b) in l1.iter().zip(l16) {
            assert!((a - b).abs() < 1e-4, "batch-1 vs batch-16 logits diverge: {a} vs {b}");
        }
    }
}
