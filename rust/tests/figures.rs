//! Integration: every figure renderer produces the paper-shaped output,
//! end to end through the public API (no artifacts needed).

use stt_ai::report;

fn render<T>(f: impl FnOnce(&mut Vec<u8>) -> std::io::Result<T>) -> (T, String) {
    let mut buf = Vec::new();
    let v = f(&mut buf).expect("renderer failed");
    (v, String::from_utf8(buf).unwrap())
}

#[test]
fn fig10_has_19_rows_and_total() {
    let (rows, text) = render(report::fig10);
    assert_eq!(rows.len(), 19);
    assert!(text.contains("Fig. 10"));
    assert!(text.contains("VGG16"));
    assert!(text.contains("zoo total bf16"));
}

#[test]
fn fig11_reports_12mb_coverage() {
    let (rows, text) = render(report::fig11);
    assert_eq!(rows.len(), 19);
    assert!(text.contains("12 MB serves"));
    // Every model's requirement grows with batch.
    for (_, series) in rows {
        assert!(series.windows(2).all(|w| w[1].1 >= w[0].1));
    }
}

#[test]
fn fig12_covers_both_dtypes_and_batches() {
    let (rows, text) = render(report::fig12);
    // 19 models × 4 batches × 2 dtypes.
    assert_eq!(rows.len(), 19 * 4 * 2);
    assert!(text.contains("dtype Int8") && text.contains("dtype Bf16"));
    // int8 spill ≤ bf16 spill for the same model/batch.
    for i in 0..(19 * 4) {
        assert!(rows[i].spill_bytes <= rows[i + 19 * 4].spill_bytes);
    }
}

#[test]
fn fig13_worst_case_under_paper_bound() {
    let (rows, text) = render(report::fig13);
    assert_eq!(rows.len(), 19);
    assert!(text.contains("worst case"));
    assert!(rows.iter().all(|r| r.max_t_ret < 1.6));
}

#[test]
fn fig14_series_shapes() {
    let ((a, b), _) = render(report::fig14);
    assert!(a.windows(2).all(|w| w[1].1 <= w[0].1), "14a decreasing: {a:?}");
    assert!(b.windows(2).all(|w| w[1].1 >= w[0].1), "14b increasing: {b:?}");
}

#[test]
fn fig15_both_base_cases() {
    let (sweeps, text) = render(report::fig15);
    assert_eq!(sweeps.len(), 2);
    assert!(text.contains("sakhare2020") && text.contains("wei2019"));
    assert!(text.contains("weight-NVM"));
}

#[test]
fn fig16_energy_and_area_ratios() {
    let (rows, text) = render(report::fig16);
    assert!(text.contains("GLB") && text.contains("LSB"));
    let at_12mb: Vec<_> =
        rows.iter().filter(|r| r.capacity_bytes == 12 * 1024 * 1024).collect();
    assert_eq!(at_12mb.len(), 2);
    for r in at_12mb {
        assert!(r.area_ratio() > 10.0);
        assert!(r.energy_ratio() > 1.0);
    }
}

#[test]
fn fig17_relaxed_vs_tight() {
    let (sweeps, _) = render(report::fig17);
    assert_eq!(sweeps.len(), 2);
    let (relaxed, tight) = (&sweeps[0], &sweeps[1]);
    for (r, t) in relaxed.write_pulse.iter().zip(&tight.write_pulse) {
        assert!(r.1 <= t.1, "relaxed BER must not need longer writes");
    }
}

#[test]
fn fig18_counts_fits() {
    let (rows, text) = render(report::fig18);
    assert_eq!(rows.len(), 19);
    assert!(text.contains("fit the 52 KB"));
}

#[test]
fn fig19_ordering() {
    let (row, text) = render(report::fig19);
    assert!(text.contains("ResNet-50"));
    assert!(row.mram_scratchpad.total() < row.mram.total());
    assert!(row.mram.total() < row.sram.total());
}

#[test]
fn table3_savings() {
    let rows = report::table3_rows();
    let (a, p) = rows[1].savings_vs(&rows[0]);
    assert!(a > 0.7 && p > 0.02);
    let (a2, p2) = rows[2].savings_vs(&rows[0]);
    assert!(a2 > a && p2 > p);
}
