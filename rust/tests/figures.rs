//! Integration: the engine-driven figure renderers are byte-identical to the
//! frozen pre-refactor serial renderers (`report::legacy`), parallel output
//! is byte-identical to serial output, and the unified `SweepResult` records
//! keep the paper-shaped invariants the old ad-hoc rows carried.

use stt_ai::dse::engine::{self, Runner, SweepResult};
use stt_ai::report::{self, figures, legacy};

fn legacy_text(n: u32) -> String {
    let mut buf = Vec::new();
    match n {
        10 => {
            legacy::fig10(&mut buf).unwrap();
        }
        11 => {
            legacy::fig11(&mut buf).unwrap();
        }
        12 => {
            legacy::fig12(&mut buf).unwrap();
        }
        13 => {
            legacy::fig13(&mut buf).unwrap();
        }
        14 => {
            legacy::fig14(&mut buf).unwrap();
        }
        15 => {
            legacy::fig15(&mut buf).unwrap();
        }
        16 => {
            legacy::fig16(&mut buf).unwrap();
        }
        17 => {
            legacy::fig17(&mut buf).unwrap();
        }
        18 => {
            legacy::fig18(&mut buf).unwrap();
        }
        19 => {
            legacy::fig19(&mut buf).unwrap();
        }
        _ => unreachable!(),
    }
    String::from_utf8(buf).unwrap()
}

fn engine_text(n: u32, r: &Runner) -> String {
    let mut buf = Vec::new();
    match n {
        10 => {
            figures::fig10_with(&mut buf, r).unwrap();
        }
        11 => {
            figures::fig11_with(&mut buf, r).unwrap();
        }
        12 => {
            figures::fig12_with(&mut buf, r).unwrap();
        }
        13 => {
            figures::fig13_with(&mut buf, r).unwrap();
        }
        14 => {
            figures::fig14_with(&mut buf, r).unwrap();
        }
        15 => {
            figures::fig15_with(&mut buf, r).unwrap();
        }
        16 => {
            figures::fig16_with(&mut buf, r).unwrap();
        }
        17 => {
            figures::fig17_with(&mut buf, r).unwrap();
        }
        18 => {
            figures::fig18_with(&mut buf, r).unwrap();
        }
        19 => {
            figures::fig19_with(&mut buf, r).unwrap();
        }
        _ => unreachable!(),
    }
    String::from_utf8(buf).unwrap()
}

fn render<T>(f: impl FnOnce(&mut Vec<u8>) -> std::io::Result<T>) -> (T, String) {
    let mut buf = Vec::new();
    let v = f(&mut buf).expect("renderer failed");
    (v, String::from_utf8(buf).unwrap())
}

// ---------------------------------------------------------------------------
// Golden parity + determinism (the refactor's acceptance criteria)
// ---------------------------------------------------------------------------

#[test]
fn golden_parity_engine_matches_frozen_serial_renderers() {
    // Parallel engine output must be byte-identical to the pre-refactor
    // bespoke serial loops for every figure.
    let r = Runner::new(4);
    for n in 10..=19 {
        assert_eq!(
            engine_text(n, &r),
            legacy_text(n),
            "fig{n}: engine text diverged from the frozen pre-refactor renderer"
        );
    }
}

#[test]
fn parallel_1_and_parallel_n_are_byte_identical() {
    let serial = Runner::new(1);
    let wide = Runner::new(8);
    for n in 10..=19 {
        assert_eq!(engine_text(n, &serial), engine_text(n, &wide), "fig{n} not deterministic");
    }
}

#[test]
fn render_all_regenerates_every_figure() {
    let mut buf = Vec::new();
    report::render_all(&mut buf, &Runner::new(2)).unwrap();
    let text = String::from_utf8(buf).unwrap();
    for n in 10..=19 {
        let expected = match n {
            14 => "== Fig. 14a".to_string(),
            _ => format!("== Fig. {n}"),
        };
        assert!(text.contains(&expected), "render_all missing fig{n}");
    }
}

#[test]
fn sweep_overrides_reshape_figures() {
    // `--sweep batch=2` narrows fig11 to one batch column without touching
    // figures that don't vary a batch axis.
    let r = Runner::new(2).with_overrides(engine::parse_axes("batch=2").unwrap());
    let (rows, text) = render(|w| figures::fig11_with(w, &r));
    assert_eq!(rows.len(), 19);
    assert!(text.contains("batch: 2  (int8, bf16)"), "{text}");
    let (rows10, _) = render(|w| figures::fig10_with(w, &r));
    assert_eq!(rows10.len(), 19);
}

// ---------------------------------------------------------------------------
// Paper-shaped invariants on the unified records
// ---------------------------------------------------------------------------

#[test]
fn fig10_has_19_rows_and_total() {
    let (rows, text) = render(figures::fig10);
    assert_eq!(rows.len(), 19);
    assert!(text.contains("Fig. 10"));
    assert!(text.contains("VGG16"));
    assert!(text.contains("zoo total bf16"));
}

#[test]
fn fig11_requirement_grows_with_batch() {
    let (rows, text) = render(figures::fig11);
    assert_eq!(rows.len(), 19 * 4);
    assert!(text.contains("12 MB serves"));
    for per_model in rows.chunks(4) {
        let ws: Vec<u64> = per_model.iter().map(|r| r.metric_u64("bf16_bytes")).collect();
        assert!(ws.windows(2).all(|w| w[1] >= w[0]), "{ws:?}");
    }
}

#[test]
fn fig12_covers_both_dtypes_and_batches() {
    let (rows, text) = render(figures::fig12);
    // 2 dtypes × 19 models × 4 batches, dtype-major.
    assert_eq!(rows.len(), 2 * 19 * 4);
    assert!(text.contains("dtype Int8") && text.contains("dtype Bf16"));
    // int8 spill ≤ bf16 spill for the same model/batch.
    let half = rows.len() / 2;
    for i in 0..half {
        assert!(rows[i].metric_u64("spill_bytes") <= rows[i + half].metric_u64("spill_bytes"));
    }
}

#[test]
fn fig13_worst_case_under_paper_bound() {
    let (rows, text) = render(figures::fig13);
    assert_eq!(rows.len(), 19);
    assert!(text.contains("worst case"));
    assert!(rows.iter().all(|r| r.metric("max_t_ret_s") < 1.6));
}

#[test]
fn fig14_series_shapes() {
    let (rows, _) = render(figures::fig14);
    // 5 array sizes × 19 models, then 6 batches × 19 models.
    assert_eq!(rows.len(), 5 * 19 + 6 * 19);
    let (a, b) = rows.split_at(5 * 19);
    let worst = |group: &[SweepResult]| {
        group.iter().map(|r| r.metric("max_t_ret_s")).fold(0.0, f64::max)
    };
    let series_a: Vec<f64> = a.chunks(19).map(worst).collect();
    assert!(series_a.windows(2).all(|w| w[1] <= w[0]), "14a decreasing: {series_a:?}");
    let series_b: Vec<f64> = b.chunks(19).map(worst).collect();
    assert!(series_b.windows(2).all(|w| w[1] >= w[0]), "14b increasing: {series_b:?}");
}

#[test]
fn fig15_both_base_cases() {
    let (rows, text) = render(figures::fig15);
    assert_eq!(rows.len(), 2 * 51);
    assert!(text.contains("sakhare2020") && text.contains("wei2019"));
    assert!(text.contains("weight-NVM"));
}

#[test]
fn fig16_energy_and_area_ratios() {
    let (rows, text) = render(figures::fig16);
    assert!(text.contains("GLB") && text.contains("LSB"));
    let at_12mb: Vec<&SweepResult> =
        rows.iter().filter(|r| r.point.glb_mb == Some(12)).collect();
    assert_eq!(at_12mb.len(), 2);
    for r in at_12mb {
        assert!(r.metric("sram_area_mm2") / r.metric("mram_area_mm2") > 10.0);
        assert!(r.metric("sram_energy_j") / r.metric("mram_energy_j") > 1.0);
    }
}

#[test]
fn fig17_relaxed_vs_tight() {
    let (rows, _) = render(figures::fig17);
    assert_eq!(rows.len(), 2 * 51);
    let (relaxed, tight) = rows.split_at(rows.len() / 2);
    for (r, t) in relaxed.iter().zip(tight) {
        assert!(
            r.metric("write_pulse_s") <= t.metric("write_pulse_s"),
            "relaxed BER must not need longer writes"
        );
    }
}

#[test]
fn fig18_counts_fits() {
    let (rows, text) = render(figures::fig18);
    assert_eq!(rows.len(), 19);
    assert!(text.contains("fit the 52 KB"));
}

#[test]
fn fig19_ordering() {
    let (rows, text) = render(figures::fig19);
    assert!(text.contains("ResNet-50"));
    let rec = &rows[0];
    assert!(engine::ledger_total(rec, "mram_sp") < engine::ledger_total(rec, "mram"));
    assert!(engine::ledger_total(rec, "mram") < engine::ledger_total(rec, "sram"));
}

#[test]
fn table3_savings() {
    let rows = report::table3_rows();
    let (a, p) = rows[1].savings_vs(&rows[0]);
    assert!(a > 0.7 && p > 0.02);
    let (a2, p2) = rows[2].savings_vs(&rows[0]);
    assert!(a2 > a && p2 > p);
}
