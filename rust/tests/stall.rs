//! Integration coverage of the write-bandwidth stall model: zero-stall
//! parity with the pure compute walk, monotonicity in the write pulse and
//! in the traffic volume, and byte-stability of the re-derived selection
//! records across worker counts.

use stt_ai::accel::{ArrayConfig, ModelTraffic, RetentionAnalysis};
use stt_ai::dse::engine::{shared_zoo, Runner};
use stt_ai::dse::select;
use stt_ai::memsys::{GlbBandwidth, GlbKind, Scratchpad};
use stt_ai::models::{self, DType};
use stt_ai::util::units::MB;

/// Infinite bandwidth with no scratchpad reproduces the pre-stall latency
/// exactly — bit for bit, for every zoo model.
#[test]
fn zero_stall_parity_across_the_zoo() {
    let a = ArrayConfig::paper_42x42();
    let free = GlbBandwidth::unconstrained();
    for m in &models::zoo() {
        let ra = RetentionAnalysis::new(&a, 16);
        let traffic = ModelTraffic::analyze(m, &a, DType::Bf16, 16, 12 * MB);
        let stalled = ra.inference_latency_stalled(m, &traffic, &free, None);
        assert_eq!(stalled.stall_s, 0.0, "{}", m.name);
        assert_eq!(stalled.total(), ra.inference_latency(m), "{}", m.name);
    }
}

/// Latency is non-decreasing in the write pulse: throttling the write
/// service rate can only grow the stall, never shrink it.
#[test]
fn latency_monotone_in_write_pulse() {
    let a = ArrayConfig::with_mac_array(84);
    let m = models::by_name("ResNet50").unwrap();
    let ra = RetentionAnalysis::new(&a, 16);
    let traffic = ModelTraffic::analyze(&m, &a, DType::Bf16, 16, 12 * MB);
    let base = GlbBandwidth::of(&GlbKind::stt_ai(), 1.0e-8, 1.0e-5);
    let sp = Scratchpad::paper_bf16();
    let mut last = 0.0;
    for throttle in [1.0, 2.0, 4.0, 16.0, 256.0] {
        let bw = GlbBandwidth {
            write_bytes_per_s: base.write_bytes_per_s / throttle,
            read_bytes_per_s: base.read_bytes_per_s,
        };
        let stalled = ra.inference_latency_stalled(&m, &traffic, &bw, Some(&sp));
        assert!(
            stalled.stall_s >= last,
            "throttle {throttle}: stall {} < {last}",
            stalled.stall_s
        );
        last = stalled.stall_s;
    }
    // At the heaviest throttle the stall dominates visibly.
    assert!(last > 0.0);
}

/// Latency is non-decreasing in the traffic volume (training-style write
/// intensities can only add stall).
#[test]
fn latency_monotone_in_traffic() {
    let a = ArrayConfig::with_mac_array(84);
    let m = models::by_name("ResNet50").unwrap();
    let ra = RetentionAnalysis::new(&a, 16);
    let base = ModelTraffic::analyze(&m, &a, DType::Bf16, 16, 12 * MB);
    let bw = GlbBandwidth::of(&GlbKind::stt_ai(), 1.0e-8, 1.0e-5);
    let sp = Scratchpad::paper_bf16();
    let mut last = 0.0;
    for wi in [1.0, 1.5, 2.5, 4.0] {
        let traffic = base.with_write_intensity(wi);
        let stalled = ra.inference_latency_stalled(&m, &traffic, &bw, Some(&sp));
        assert!(stalled.stall_s >= last, "wi {wi}: stall {} < {last}", stalled.stall_s);
        last = stalled.stall_s;
    }
    assert!(last > 0.0);
}

/// The re-derived selection records — stall-scored latency included — are
/// byte-stable across worker counts, and every candidate carries the stall
/// decomposition metrics.
#[test]
fn selection_records_carry_stalls_and_are_worker_invariant() {
    let zoo = shared_zoo();
    let spec = select::spec_selection(&zoo);
    let serial = Runner::new(1).run(spec.clone());
    let parallel = Runner::new(4).run(spec);
    assert_eq!(serial, parallel, "stall-scored records must be byte-stable");
    for r in &serial {
        assert!(r.metric_opt("stall_s").is_some(), "{:?}", r.point);
        assert!(r.metric_opt("compute_latency_s").is_some());
        assert!(
            r.metric("latency_s") >= r.metric("compute_latency_s"),
            "stall can only add latency: {:?}",
            r.point
        );
    }
    // Somewhere in the grid the stall is real (the 84×84 MRAM corner).
    assert!(serial.iter().any(|r| r.metric("stall_s") > 0.0));
}
