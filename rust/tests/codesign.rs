//! Cross-module co-design invariants: the contracts §III–§V rely on between
//! the accelerator model, the device model, and the memory system.

use stt_ai::accel::{ArrayConfig, ModelTraffic, RetentionAnalysis};
use stt_ai::config::SystemConfig;
use stt_ai::dse::delta::paper_design_points;
use stt_ai::dse::retention;
use stt_ai::memsys::{Scratchpad, TechnologyId};
use stt_ai::models::{self, DType};
use stt_ai::mram::{DesignTargets, MtjTech, ScalingSolver};
use stt_ai::util::units::{KB, MB};

/// §V.C's central claim: the Δ=19.5 GLB design (3 s at 1e-8) covers the
/// worst data occupancy of ALL 19 models at the paper's operating point —
/// with margin.
#[test]
fn glb_design_covers_worst_zoo_occupancy() {
    let zoo = models::zoo();
    let worst = retention::fig13(&zoo).iter().map(|r| r.max_t_ret).fold(0.0, f64::max);
    let solver = ScalingSolver::new(MtjTech::sakhare2020());
    let d = solver.solve(&DesignTargets::global_buffer());
    assert!(
        d.achieved_retention > 1.5 * worst,
        "retention {} must cover worst occupancy {} with margin",
        d.achieved_retention,
        worst
    );
}

/// The LSB bank (Δ=12.5 @ 1e-5) must also cover the occupancy — relaxing
/// BER, not retention, is what makes Ultra safe.
#[test]
fn lsb_bank_still_covers_occupancy() {
    let zoo = models::zoo();
    let worst = retention::fig13(&zoo).iter().map(|r| r.max_t_ret).fold(0.0, f64::max);
    let solver = ScalingSolver::new(MtjTech::sakhare2020());
    let d = solver.solve(&DesignTargets::lsb_bank());
    assert!(d.achieved_retention > worst, "{} vs {}", d.achieved_retention, worst);
}

/// The paper's scratchpad (52 KB) covers the partial ofmaps of exactly the
/// models the GLB capacity analysis targets; overflow goes to the GLB and
/// the traffic model accounts for every byte.
#[test]
fn scratchpad_traffic_conservation() {
    let a = ArrayConfig::paper_42x42();
    let sp = Scratchpad::paper_bf16();
    for m in models::zoo() {
        let t = ModelTraffic::analyze(&m, &a, DType::Bf16, 4, 12 * MB);
        for l in &t.layers {
            let split =
                stt_ai::memsys::TrafficSplit::split(l.partial_bytes, l.partial_rounds, &sp);
            assert_eq!(
                split.total_partial_bytes(),
                l.partial_bytes * l.partial_rounds,
                "{}/{}",
                m.name,
                l.name
            );
            if l.partial_bytes <= 52 * KB {
                assert_eq!(split.glb_overflow_writes, 0, "{}/{}", m.name, l.name);
            }
        }
    }
}

/// Table III consistency: the SystemConfig-composed buffer systems match
/// the GLB kinds they claim.
#[test]
fn system_configs_compose_expected_arrays() {
    let base = SystemConfig::paper_baseline().buffer_system();
    assert_eq!(base.glb_arrays()[0].tech, TechnologyId::Sram);
    let ai = SystemConfig::paper_stt_ai().buffer_system();
    let glb = ai.glb_arrays()[0];
    assert!(glb.tech.is_stt() && (glb.delta_guard_banded - 27.5).abs() < 1e-9);
    let ultra = SystemConfig::paper_stt_ai_ultra().buffer_system();
    let deltas: Vec<f64> = ultra
        .glb_arrays()
        .iter()
        .map(|a| {
            assert!(a.tech.is_stt(), "ultra banks must be MRAM");
            a.delta_guard_banded
        })
        .collect();
    assert_eq!(deltas, vec![27.5, 17.5]);
    // Capacity is conserved across the split.
    let total: u64 = ultra.glb_arrays().iter().map(|a| a.capacity_bytes).sum();
    assert_eq!(total, 12 * MB);
}

/// The weight-NVM design point retains through years of model lifetime at
/// both base technologies (§V.C "models are replaced frequently").
#[test]
fn weight_nvm_across_technologies() {
    for tech in [MtjTech::sakhare2020(), MtjTech::wei2019()] {
        let pts = paper_design_points(tech);
        let nvm = &pts[0];
        assert!(
            nvm.achieved_retention > 2.9 * 365.25 * 24.0 * 3600.0,
            "{}: {}",
            tech.name,
            nvm.achieved_retention
        );
        // All three points keep the Δ ordering NVM > GLB > LSB.
        assert!(pts[0].delta_scaled > pts[1].delta_scaled);
        assert!(pts[1].delta_scaled > pts[2].delta_scaled);
    }
}

/// Timing model vs traffic model: a layer with more array steps must create
/// at least as much partial-ofmap traffic (they share steps_per_out_ch).
#[test]
fn timing_and_traffic_agree_on_steps() {
    let a = ArrayConfig::paper_42x42();
    let m = models::by_name("ResNet50").unwrap();
    let ra = RetentionAnalysis::new(&a, 1);
    let timings = ra.layer_timings(&m);
    let traffic = ModelTraffic::analyze(&m, &a, DType::Bf16, 1, 12 * MB);
    let conv_timings: Vec<_> = timings.iter().filter(|t| t.is_conv).collect();
    assert_eq!(conv_timings.len(), traffic.layers.len());
    for (t, l) in conv_timings.iter().zip(&traffic.layers) {
        assert_eq!(t.name, l.name);
        if t.steps_per_out_ch <= 1 {
            assert_eq!(l.partial_rounds, 0, "{}", l.name);
        } else {
            assert!(l.partial_rounds > 0, "{}", l.name);
        }
    }
}
