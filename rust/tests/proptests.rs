//! Property-style randomized tests (offline build: no proptest crate; the
//! same discipline — random inputs, many cases, explicit invariants — using
//! the crate's own deterministic RNG, with the failing seed printed).

use stt_ai::accel::{ArrayConfig, RetentionAnalysis};
use stt_ai::ber::Injector;
use stt_ai::coordinator::{Batcher, Request};
use stt_ai::dse::{kernels, select, Constraint, DesignPoint, Objective, SweepColumns, SweepResult};
use stt_ai::models;
use stt_ai::mram::{
    read_disturb_prob, read_pulse_at_rd, retention_failure_prob, retention_time_at_ber,
    write_error_rate, write_pulse_at_wer, PtVariation,
};
use stt_ai::util::json::Json;
use stt_ai::util::pool::ThreadPool;
use stt_ai::util::rng::Rng;

const CASES: usize = 200;

#[test]
fn prop_retention_inverse_roundtrip() {
    let mut rng = Rng::seed_from_u64(0x5151);
    for case in 0..CASES {
        let delta = rng.range_f64(5.0, 80.0);
        let tau = 10f64.powf(rng.range_f64(-9.0, 0.0));
        let ber = 10f64.powf(rng.range_f64(-12.0, -2.0));
        let t = retention_time_at_ber(tau, delta, ber);
        let p = retention_failure_prob(t, tau, delta);
        assert!((p / ber - 1.0).abs() < 1e-6, "case {case}: delta={delta} tau={tau} ber={ber}");
    }
}

#[test]
fn prop_wer_inverse_and_monotonicity() {
    let mut rng = Rng::seed_from_u64(0xBEEF);
    for case in 0..CASES {
        let delta = rng.range_f64(5.0, 80.0);
        let i = rng.range_f64(1.2, 5.0);
        let wer = 10f64.powf(rng.range_f64(-12.0, -3.0));
        let t = write_pulse_at_wer(wer, 1e-9, delta, i);
        if t > 0.0 {
            let w = write_error_rate(t, 1e-9, delta, i);
            assert!((w / wer - 1.0).abs() < 1e-5, "case {case}");
            // Longer pulse → strictly lower WER.
            assert!(write_error_rate(t * 1.5, 1e-9, delta, i) < w, "case {case}");
        }
    }
}

#[test]
fn prop_read_disturb_bounds_and_inverse() {
    let mut rng = Rng::seed_from_u64(0xD15C);
    for case in 0..CASES {
        let delta = rng.range_f64(5.0, 80.0);
        let r = rng.range_f64(0.05, 0.9);
        let p = 10f64.powf(rng.range_f64(-12.0, -3.0));
        let t = read_pulse_at_rd(p, 1e-9, delta, r);
        let back = read_disturb_prob(t, 1e-9, delta, r);
        assert!((back / p - 1.0).abs() < 1e-6, "case {case}");
        // Probabilities stay in [0,1] over wild pulse widths.
        let p2 = read_disturb_prob(t * 1e6, 1e-9, delta, r);
        assert!((0.0..=1.0).contains(&p2), "case {case}: {p2}");
    }
}

#[test]
fn prop_guard_band_closes_the_loop() {
    // For any Δ_scaled and any variation setting, the hot/−nσ corner of the
    // guard-banded design recovers at least Δ_scaled (Eq. 17's contract).
    let mut rng = Rng::seed_from_u64(0x6B);
    for case in 0..CASES {
        let v = PtVariation {
            sigma_frac: rng.range_f64(0.0, 0.05),
            n_sigma: rng.range_f64(0.0, 6.0),
            t_nom: 300.0,
            t_hot: rng.range_f64(300.0, 420.0),
            t_cold: rng.range_f64(230.0, 300.0),
        };
        if 1.0 - v.n_sigma * v.sigma_frac <= 0.05 {
            continue; // guard fraction out of physical range
        }
        let delta_scaled = rng.range_f64(10.0, 60.0);
        let gb = v.guard_band(delta_scaled);
        let worst = v.delta_at(gb.delta_guard_banded, -v.n_sigma, v.t_hot);
        assert!(worst >= delta_scaled * (1.0 - 1e-9), "case {case}: {worst} < {delta_scaled}");
        assert!(gb.delta_pt_max >= gb.delta_guard_banded * (1.0 - 1e-9), "case {case}");
    }
}

#[test]
fn prop_injector_flip_rate_tracks_ber() {
    let mut rng = Rng::seed_from_u64(0xF1);
    for case in 0..20 {
        let ber = 10f64.powf(rng.range_f64(-4.0, -2.0));
        let n = 1usize << 18;
        let mut buf = vec![0u8; n];
        let stats = Injector::new(case as u64).flip(&mut buf, ber);
        let expect = (n * 8) as f64 * ber;
        let sigma = expect.sqrt();
        assert!(
            (stats.bits_flipped as f64 - expect).abs() < 6.0 * sigma,
            "case {case}: flips={} expect={expect}",
            stats.bits_flipped
        );
        // Popcount agrees with the reported count (no double flips).
        let ones: u64 = buf.iter().map(|b| b.count_ones() as u64).sum();
        assert_eq!(ones, stats.bits_flipped, "case {case}");
    }
}

#[test]
fn prop_batcher_never_loses_or_reorders() {
    let mut rng = Rng::seed_from_u64(0xBA7C);
    for case in 0..50 {
        let max_batch = 1 + rng.below(8) as usize;
        let mut b = Batcher::new(max_batch, std::time::Duration::ZERO, 1, usize::MAX);
        let n = 1 + rng.below(64);
        let now = stt_ai::util::clock::Tick::ZERO;
        for id in 0..n {
            assert!(b.push(Request::new(id, vec![0.0], now)));
        }
        let mut seen = Vec::new();
        while let Some(batch) = b.form(max_batch, now) {
            assert!(batch.real <= max_batch);
            assert_eq!(batch.images.len(), max_batch);
            seen.extend(batch.ids);
        }
        let want: Vec<u64> = (0..n).collect();
        assert_eq!(seen, want, "case {case}: FIFO order must hold");
    }
}

/// Weighted deficit-round-robin admission: with every class backlogged,
/// the dequeue stream is exactly weight-proportional — over two full
/// cursor cycles each class contributes exactly `2 * weight` rows (so a
/// positive-weight tenant can never starve, whatever the mix), and the
/// per-class order stays FIFO.
#[test]
fn prop_weighted_drr_never_starves_a_backlogged_class() {
    let mut rng = Rng::seed_from_u64(0xD2D2);
    for case in 0..50 {
        let classes = 2 + rng.below(5) as usize;
        let weights: Vec<u64> = (0..classes).map(|_| 1 + rng.below(9)).collect();
        let max_batch = 1 + rng.below(8) as usize;
        let mut b = Batcher::with_weights(
            max_batch,
            std::time::Duration::ZERO,
            1,
            usize::MAX,
            &weights,
        );
        let now = stt_ai::util::clock::Tick::ZERO;
        // Adversarial backlog: every class queues more rows than two full
        // service cycles can drain, so no queue empties mid-measurement.
        let per_class = 2 * (*weights.iter().max().unwrap() as usize) + 4;
        let mut id = 0u64;
        for _ in 0..per_class {
            for t in 0..classes {
                assert!(b.push(Request::for_tenant(id, t as u32, vec![0.0], now)));
                id += 1;
            }
        }
        let quota: usize = 2 * weights.iter().sum::<u64>() as usize;
        let mut stream: Vec<(u64, u32)> = Vec::new();
        while stream.len() < quota {
            let batch = b.form(max_batch, now).expect("backlog keeps batches coming");
            stream.extend(batch.ids.iter().copied().zip(batch.tenants.iter().copied()));
        }
        stream.truncate(quota);
        let mut counts = vec![0u64; classes];
        let mut last_id = vec![None::<u64>; classes];
        for &(id, t) in &stream {
            counts[t as usize] += 1;
            if let Some(prev) = last_id[t as usize] {
                assert!(prev < id, "case {case}: class {t} reordered ({prev} after {id})");
            }
            last_id[t as usize] = Some(id);
        }
        for (t, (&got, &w)) in counts.iter().zip(&weights).enumerate() {
            assert_eq!(
                got,
                2 * w,
                "case {case}: class {t} got {got} of {quota} rows (weights {weights:?})"
            );
        }
    }
}

#[test]
fn prop_json_roundtrip_random_trees() {
    fn gen(rng: &mut Rng, depth: u32) -> Json {
        match if depth == 0 { rng.below(4) } else { rng.below(6) } {
            0 => Json::Null,
            1 => Json::Bool(rng.below(2) == 1),
            2 => Json::Num((rng.below(1_000_000) as f64) - 500_000.0),
            3 => Json::Str(format!("s{}-\"esc\\{}", rng.below(100), rng.below(10))),
            4 => Json::Arr((0..rng.below(4)).map(|_| gen(rng, depth - 1)).collect()),
            _ => Json::Obj(
                (0..rng.below(4)).map(|i| (format!("k{i}"), gen(rng, depth - 1))).collect(),
            ),
        }
    }
    let mut rng = Rng::seed_from_u64(0x150);
    for case in 0..CASES {
        let v = gen(&mut rng, 3);
        let text = v.to_string();
        let back = Json::parse(&text).unwrap_or_else(|e| panic!("case {case}: {e}\n{text}"));
        assert_eq!(back, v, "case {case}");
    }
}

#[test]
fn prop_retention_monotone_in_array_and_batch() {
    // Random (model, array, batch) triples: growing the array never grows
    // retention; growing the batch never shrinks it.
    let zoo = models::zoo();
    let mut rng = Rng::seed_from_u64(0xACC);
    for case in 0..40 {
        let m = &zoo[rng.below(zoo.len() as u64) as usize];
        let macs = 14 + 7 * rng.below(12);
        let batch = 1 + rng.below(32);
        let a1 = ArrayConfig::with_mac_array(macs);
        let a2 = ArrayConfig::with_mac_array(macs * 2);
        let r1 = RetentionAnalysis::new(&a1, batch).analyze(m).max_t_ret();
        let r2 = RetentionAnalysis::new(&a2, batch).analyze(m).max_t_ret();
        assert!(r2 <= r1 * (1.0 + 1e-12), "case {case} ({}): {r2} > {r1}", m.name);
        let rb = RetentionAnalysis::new(&a1, batch + 1).analyze(m).max_t_ret();
        assert!(rb >= r1 * (1.0 - 1e-12), "case {case} ({})", m.name);
    }
}

#[test]
fn prop_sweep_columns_round_trip_is_lossless() {
    // SoA↔AoS: random record batches with random metric-key subsets in
    // random per-record order, values including genuine NaNs, and mixed
    // sweep names — `SweepColumns::from_results(..).to_results()` must
    // reproduce every record bit for bit, and per-row column probes must
    // agree with the per-record linear scans.
    const POOL: [&str; 5] = ["a", "b", "c", "d", "e"];
    let mut rng = Rng::seed_from_u64(0x50A_0A05);
    for case in 0..CASES {
        let n = rng.below(12) as usize;
        let records: Vec<SweepResult> = (0..n)
            .map(|_| {
                // Partial Fisher–Yates: a random-size subset of POOL in a
                // random order, no duplicates.
                let mut keys: Vec<&'static str> = POOL.to_vec();
                let take = rng.below(POOL.len() as u64 + 1) as usize;
                for i in 0..take {
                    let j = i + rng.below((POOL.len() - i) as u64) as usize;
                    keys.swap(i, j);
                }
                let metrics = keys[..take]
                    .iter()
                    .map(|&k| {
                        let v = if rng.below(8) == 0 {
                            f64::NAN
                        } else {
                            rng.range_f64(-1.0e9, 1.0e9)
                        };
                        (k, v)
                    })
                    .collect();
                SweepResult {
                    sweep: if rng.below(4) == 0 { "alt".into() } else { "main".into() },
                    point: DesignPoint { batch: Some(1 + rng.below(32)), ..Default::default() },
                    metrics,
                }
            })
            .collect();
        let cols = SweepColumns::from_results(&records);
        assert_eq!(cols.len(), records.len(), "case {case}");
        let back = cols.to_results();
        assert_eq!(back.len(), records.len(), "case {case}");
        for (row, (b, o)) in back.iter().zip(&records).enumerate() {
            assert_eq!(b.sweep, o.sweep, "case {case} row {row}");
            assert_eq!(b.point, o.point, "case {case} row {row}");
            assert_eq!(b.metrics.len(), o.metrics.len(), "case {case} row {row}");
            for ((bk, bv), (ok, ov)) in b.metrics.iter().zip(&o.metrics) {
                assert_eq!(bk, ok, "case {case} row {row}");
                assert_eq!(bv.to_bits(), ov.to_bits(), "case {case} row {row} key {ok}");
            }
            // Column probes == record scans, presence included.
            for key in POOL {
                let col = cols.value(row, key).map(f64::to_bits);
                let rec = o.metric_opt(key).map(f64::to_bits);
                assert_eq!(col, rec, "case {case} row {row} key {key}");
            }
        }
    }
}

#[test]
fn prop_strided_split_matches_copy_based_masked_split() {
    // The §Perf fast path: for identical seeds the geometric-gap walk visits
    // the same eligible-bit indices, so the in-place strided injection over
    // an interleaved [lsb, msb, lsb, msb, ...] word buffer must flip exactly
    // the bits the copy-based deinterleave-flip-reinterleave split flips —
    // same counts, same positions. This pins flip_strided against the
    // flip_masked reference the bank split used before going in-place.
    let mut rng = Rng::seed_from_u64(0x57_101D);
    for case in 0..40 {
        let words = 1 + rng.below(2048) as usize;
        let ber = 10f64.powf(rng.range_f64(-5.0, -1.5));
        let seed_lsb = rng.next_u64();
        let seed_msb = rng.next_u64();
        let mut interleaved = vec![0u8; words * 2];
        for byte in interleaved.iter_mut() {
            *byte = rng.next_u64() as u8;
        }
        // Reference: copy each lane out, flip the whole lane, copy back.
        let mut lsb: Vec<u8> = interleaved.iter().step_by(2).copied().collect();
        let mut msb: Vec<u8> = interleaved.iter().skip(1).step_by(2).copied().collect();
        let r_l = Injector::new(seed_lsb).flip_masked(&mut lsb, ber, 0xFF);
        let r_m = Injector::new(seed_msb).flip_masked(&mut msb, ber, 0xFF);
        // Fast path: in place on the interleaved buffer.
        let mut fast = interleaved.clone();
        let f_l = Injector::new(seed_lsb).flip_strided(&mut fast, ber, 0, 2);
        let f_m = Injector::new(seed_msb).flip_strided(&mut fast, ber, 1, 2);
        assert_eq!(r_l.bits_flipped, f_l.bits_flipped, "case {case}: lsb count");
        assert_eq!(r_m.bits_flipped, f_m.bits_flipped, "case {case}: msb count");
        assert_eq!(r_l.bits_scanned, f_l.bits_scanned, "case {case}: lsb scanned");
        assert_eq!(r_m.bits_scanned, f_m.bits_scanned, "case {case}: msb scanned");
        for i in 0..words {
            assert_eq!(lsb[i], fast[2 * i], "case {case}: lsb byte {i}");
            assert_eq!(msb[i], fast[2 * i + 1], "case {case}: msb byte {i}");
        }
    }
}

// ---------------------------------------------------------------------------
// Selection kernels (PR 7): the fused/tiled columnar hot path must be
// bit-identical to the per-record scalar scans on adversarial batches —
// metric holes, genuine NaN values, heavy ties from a small value pool, and
// row counts straddling the TILE=64 boundary — at every worker count.
// ---------------------------------------------------------------------------

/// The real selection-record metric vocabulary (what `spec_selection`
/// sweeps emit), so the generated batches exercise the same compiled
/// constraint keys as production.
const SELECTION_KEYS: [&str; 7] = [
    "accel_area_mm2",
    "buffer_energy_j",
    "latency_s",
    "throughput_rps",
    "est_accuracy",
    "retention_at_ber_s",
    "occupancy_s",
];

/// Random selection-shaped batch: each record carries a random subset of
/// [`SELECTION_KEYS`] (~1-in-6 holes), values drawn from the tiny pool
/// {1,2,3,4} to force ties, with ~1-in-12 genuine NaNs. Points are unique
/// per row (batch = row+1) so a winner can be identified by its point.
fn gen_selection_batch(rng: &mut Rng, n: usize) -> Vec<SweepResult> {
    (0..n)
        .map(|row| {
            let mut metrics: Vec<(&'static str, f64)> = Vec::new();
            for &k in SELECTION_KEYS.iter() {
                if rng.below(6) == 0 {
                    continue; // hole: this record never carries k
                }
                let v = if rng.below(12) == 0 { f64::NAN } else { 1.0 + rng.below(4) as f64 };
                metrics.push((k, v));
            }
            SweepResult {
                sweep: "prop".into(),
                point: DesignPoint { batch: Some(row as u64 + 1), ..Default::default() },
                metrics,
            }
        })
        .collect()
}

/// Random constraint set over the generated value pool (floors/caps sit
/// mid-pool so roughly half the rows pass each check). The power cap's
/// metric is never generated, so when it appears it exercises the
/// compiled-`Never` screen (everything infeasible).
fn gen_constraints(rng: &mut Rng) -> Vec<Constraint> {
    let mut c = Vec::new();
    if rng.below(2) == 0 {
        c.push(Constraint::MinAccuracy(2.0));
    }
    if rng.below(2) == 0 {
        c.push(Constraint::RetentionCoversOccupancy);
    }
    if rng.below(2) == 0 {
        c.push(Constraint::MaxAreaMm2(3.0));
    }
    if rng.below(8) == 0 {
        c.push(Constraint::MaxPowerMw(2.0));
    }
    c
}

/// Reference frontier with the documented hole semantics, built from
/// per-record probes and the pre-kernel scalar dominance scan: an objective
/// is live when some subset row carries its metric; subset rows missing a
/// live metric are excluded; complete rows are compared through signed
/// (smaller-is-better) values.
fn reference_pareto(records: &[SweepResult], objectives: &[Objective], rows: &[usize]) -> Vec<bool> {
    let mut live: Vec<(&'static str, bool)> = Vec::new();
    for o in objectives {
        if !live.iter().any(|&(m, _)| m == o.metric())
            && rows.iter().any(|&r| records[r].metric_opt(o.metric()).is_some())
        {
            live.push((o.metric(), o.lower_is_better()));
        }
    }
    if live.is_empty() {
        return vec![true; rows.len()];
    }
    let mut mask = vec![false; rows.len()];
    let complete: Vec<usize> = (0..rows.len())
        .filter(|&i| live.iter().all(|&(m, _)| records[rows[i]].metric_opt(m).is_some()))
        .collect();
    if complete.is_empty() {
        return mask;
    }
    let signed: Vec<Vec<f64>> = live
        .iter()
        .map(|&(m, lower)| {
            complete
                .iter()
                .map(|&i| {
                    let v = records[rows[i]].metric_opt(m).expect("complete row carries m");
                    if lower {
                        v
                    } else {
                        -v
                    }
                })
                .collect()
        })
        .collect();
    for (&i, keep) in complete.iter().zip(kernels::scalar::nondominated(&signed)) {
        mask[i] = keep;
    }
    mask
}

/// Reference `select()`: per-record feasibility fold → [`reference_pareto`]
/// over the feasible subset → first-wins `total_cmp` argmin of the
/// requested objective over frontier rows that carry it. `None` exactly
/// when `select()` errors (no feasible row, objective metric absent, or a
/// frontier without the metric).
fn reference_select<'a>(
    records: &'a [SweepResult],
    objective: Objective,
    constraints: &[Constraint],
) -> Option<&'a SweepResult> {
    let feasible: Vec<usize> = (0..records.len())
        .filter(|&i| constraints.iter().all(|c| c.satisfied(&records[i])))
        .collect();
    if feasible.is_empty() {
        return None;
    }
    let frontier = reference_pareto(records, &Objective::all(), &feasible);
    let mut best: Option<(usize, f64)> = None;
    for (j, &i) in feasible.iter().enumerate() {
        if !frontier[j] {
            continue;
        }
        let Some(v) = records[i].metric_opt(objective.metric()) else { continue };
        let key = if objective.lower_is_better() { v } else { -v };
        let better = match best {
            Some((_, b)) => key.total_cmp(&b) == std::cmp::Ordering::Less,
            None => true,
        };
        if better {
            best = Some((i, key));
        }
    }
    best.map(|(i, _)| &records[i])
}

#[test]
fn prop_fused_feasibility_matches_the_scalar_fold() {
    let mut rng = Rng::seed_from_u64(0xFEA5_1B1E);
    for case in 0..CASES {
        let n = 1 + rng.below(96) as usize;
        let records = gen_selection_batch(&mut rng, n);
        let constraints = gen_constraints(&mut rng);
        let cols = SweepColumns::from_results(&records);
        let fused = select::feasible_mask_columns(&cols, &constraints);
        let per_row: Vec<bool> = (0..n)
            .map(|row| constraints.iter().all(|c| c.satisfied_at(&cols, row)))
            .collect();
        let per_record: Vec<bool> =
            records.iter().map(|r| constraints.iter().all(|c| c.satisfied(r))).collect();
        assert_eq!(fused, per_row, "case {case}: fused vs columnar fold ({constraints:?})");
        assert_eq!(fused, per_record, "case {case}: fused vs record fold ({constraints:?})");
    }
}

#[test]
fn prop_tiled_pareto_matches_scalar_at_every_worker_count() {
    let mut rng = Rng::seed_from_u64(0x7A12E_70);
    let pools: Vec<ThreadPool> = [1, 2, 8].into_iter().map(ThreadPool::new).collect();
    for case in 0..CASES {
        let n = 1 + rng.below(96) as usize;
        let records = gen_selection_batch(&mut rng, n);
        let cols = SweepColumns::from_results(&records);
        let objectives = Objective::all();
        let rows: Vec<usize> = (0..n).collect();
        let expect = reference_pareto(&records, &objectives, &rows);
        for pool in &pools {
            assert_eq!(
                select::pareto_mask_columns_with(&cols, &objectives, pool),
                expect,
                "case {case}: tiled frontier vs scalar reference at {} workers",
                pool.workers()
            );
        }
    }
}

#[test]
fn prop_select_winner_matches_the_reference_scan() {
    let mut rng = Rng::seed_from_u64(0x5E1E_C7);
    for case in 0..CASES {
        let n = 1 + rng.below(80) as usize;
        let records = gen_selection_batch(&mut rng, n);
        let constraints = gen_constraints(&mut rng);
        let objective = Objective::all()[rng.below(4) as usize];
        let expect = reference_select(&records, objective, &constraints);
        match (select::select("prop", &records, objective, &constraints), expect) {
            (Ok(sel), Some(rec)) => {
                assert_eq!(sel.point, rec.point, "case {case}: winner ({objective:?})");
                let want = rec.metric_opt(objective.metric()).expect("winner carries objective");
                assert_eq!(
                    sel.score.to_bits(),
                    want.to_bits(),
                    "case {case}: score must be the winner's raw metric"
                );
            }
            (Err(_), None) => {}
            (Ok(sel), None) => {
                panic!("case {case}: select picked {:?} but the reference found none", sel.point)
            }
            (Err(e), Some(rec)) => {
                panic!("case {case}: select errored ({e}) but the reference picked {:?}", rec.point)
            }
        }
    }
}
