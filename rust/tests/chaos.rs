//! Integration coverage of the deterministic fault-injection harness
//! (EXPERIMENTS.md §Robustness): the committed golden scenario parses
//! equal to its builtin, chaos reports are byte-identical across runs and
//! worker counts, the burst_ber storm degrades gracefully (retries,
//! reroutes, SRAM fallback, availability ≥ 99 %), and the `[faults]`
//! config section feeds the same run as the builtin token.

use stt_ai::config::{GlbVariant, SystemConfig, TechBase};
use stt_ai::coordinator::faults::storm_ber;
use stt_ai::coordinator::{
    ChaosConfig, EngineSpec, FaultSchedule, FleetReport, Health, Supervisor, SupervisorPolicy,
};
use stt_ai::util::clock::Clock;

const GOLDEN: &str = concat!(env!("CARGO_MANIFEST_DIR"), "/golden/chaos_burst_ber.scenario.json");

fn run_schedule(schedule: FaultSchedule, requests: usize, parallel: usize) -> FleetReport {
    let specs = EngineSpec::paper_fleet(3);
    let fallback = Some(EngineSpec::paper(GlbVariant::Sram));
    let mut sup =
        Supervisor::new(schedule, specs, fallback, SupervisorPolicy::default(), parallel)
            .expect("fleet is non-empty");
    let cfg = ChaosConfig { requests, parallel, ..Default::default() };
    sup.run(&cfg, &Clock::virtual_at_zero()).expect("chaos run")
}

fn run_scenario(name: &str, requests: usize, parallel: usize) -> FleetReport {
    run_schedule(FaultSchedule::builtin(name).expect("builtin scenario"), requests, parallel)
}

/// Every request is accounted for exactly once, and the per-engine served
/// counts cover the fleet total.
fn accounting_closes(r: &FleetReport) {
    assert_eq!(
        r.offered,
        r.served + r.dropped + r.rejected + r.malformed,
        "accounting leak in {}",
        r.scenario
    );
    let per_engine: u64 = r.engines.iter().map(|e| e.served).sum();
    assert_eq!(r.served, per_engine, "engine ledger mismatch in {}", r.scenario);
}

/// The committed golden scenario file is the burst_ber builtin, field for
/// field — and serializes back to the identical canonical JSON.
#[test]
fn golden_scenario_file_matches_the_builtin() {
    let parsed = FaultSchedule::parse(GOLDEN).expect("golden scenario parses");
    let builtin = FaultSchedule::builtin("burst_ber").unwrap();
    assert_eq!(parsed, builtin);
    assert_eq!(parsed.to_json().to_string(), builtin.to_json().to_string());
}

/// Same scenario + seed → byte-identical reports across consecutive runs
/// and across `--parallel` worker counts (the acceptance gate for the
/// harness being deterministic, not merely statistically similar).
#[test]
fn reports_are_byte_identical_across_runs_and_worker_counts() {
    let a = run_scenario("burst_ber", 600, 1);
    let b = run_scenario("burst_ber", 600, 1);
    let c = run_scenario("burst_ber", 600, 4);
    assert_eq!(a.render(), b.render(), "consecutive runs diverged");
    assert_eq!(a.render(), c.render(), "worker count leaked into the report");
    assert_eq!(a.to_json().to_string(), c.to_json().to_string());
}

/// The golden storm end-to-end: the fleet retries and reroutes around the
/// sick engines, reboots engine 0 onto the SRAM fallback, and still serves
/// ≥ 99 % of offered load with zero panics.
#[test]
fn burst_ber_storm_degrades_gracefully() {
    let r = run_scenario("burst_ber", 2000, 1);
    accounting_closes(&r);
    assert_eq!(r.offered, 2000);
    assert!(r.availability >= 99.0, "availability {:.3} < 99%", r.availability);
    assert!(r.retries > 0, "the stall window must force retries");
    assert!(r.reroutes > 0, "retries must land on a different engine");
    assert!(r.fallbacks >= 1, "engine 0 must reboot onto the SRAM fallback");
    assert!(r.canary_failures > 0, "canaries must observe the BER storm");
    let e0 = &r.engines[0];
    assert!(e0.on_fallback, "engine 0 ends the run on the fallback spec");
    let states: Vec<Health> = e0.transitions.iter().map(|&(_, h)| h).collect();
    assert!(states.contains(&Health::Degraded) && states.contains(&Health::Down));
    assert!(r.est_accuracy <= r.clean_accuracy + 1e-12);
    assert!(r.p99_us >= r.p50_us && r.max_us >= r.p99_us);
}

/// The calm control run: nothing degrades, nothing retries, accuracy is
/// the clean-BER estimate.
#[test]
fn calm_control_run_is_clean() {
    let r = run_scenario("calm", 400, 1);
    accounting_closes(&r);
    assert_eq!(r.served, 400);
    assert_eq!(r.availability, 100.0);
    assert_eq!((r.dropped, r.retries, r.reroutes, r.fallbacks, r.reboots), (0, 0, 0, 0, 0));
    assert_eq!(r.canary_failures, 0);
    assert!((r.est_accuracy - r.clean_accuracy).abs() < 1e-12);
    for e in &r.engines {
        assert_eq!(e.health, Health::Healthy, "{}", e.label);
        assert!(e.transitions.is_empty(), "{}", e.label);
    }
}

/// Every builtin scenario runs to completion with closed accounting — the
/// harness never panics under any committed fault pattern.
#[test]
fn every_builtin_scenario_survives() {
    for name in FaultSchedule::builtin_names() {
        let r = run_scenario(name, 300, 1);
        accounting_closes(&r);
        assert_eq!(r.offered, 300, "{name}");
        assert!(r.served > 0, "{name}: fleet served nothing");
    }
}

/// A `[faults]` section in a SystemConfig drives the identical run as the
/// builtin token it carries.
#[test]
fn config_faults_section_feeds_the_chaos_run() {
    let mut cfg = SystemConfig::paper_stt_ai_ultra();
    cfg.faults = Some(FaultSchedule::builtin("latency_spike").unwrap());
    let back = SystemConfig::from_json(&cfg.to_json()).expect("config roundtrip");
    let schedule = back.faults.expect("faults section survives the roundtrip");
    let a = run_schedule(schedule, 300, 1);
    let b = run_scenario("latency_spike", 300, 1);
    assert_eq!(a.render(), b.render());
}

/// Retention-storm BER closed form: zero base stays zero (volatile banks
/// are immune), the storm never shrinks the BER, deeper derates only grow
/// it, and the ceiling is the coin-flip 0.5.
#[test]
fn storm_ber_is_monotone_and_capped() {
    let tech = TechBase::from_token("stt").expect("stt tech");
    assert_eq!(storm_ber(tech, 60.0, 0.0, 1.5), 0.0);
    let base = 1.0e-8;
    let mut last = base;
    for derate in [1.0, 1.2, 1.5, 2.0, 4.0] {
        let b = storm_ber(tech, 60.0, base, derate);
        assert!(b >= last, "derate {derate}: {b:.3e} < {last:.3e}");
        assert!(b <= 0.5);
        last = b;
    }
    assert_eq!(storm_ber(tech, 500.0, 1.0e-3, 8.0), 0.5, "deep storms hit the cap");
}
