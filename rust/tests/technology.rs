//! Cross-layer integration of the pluggable memory-technology stack: the
//! same figure/table numbers through the trait (parity is asserted
//! per-figure in `tests/figures.rs`), plus the new SOT-MRAM and
//! write-intensity scenario space end to end — sweep → records → export.

use stt_ai::config::{GlbVariant, SystemConfig, TechBase, TechConfig};
use stt_ai::dse::engine::{self, Runner};
use stt_ai::memsys::TechnologyId;
use stt_ai::mram::technology::{by_token, registry, MemTechnology};
use stt_ai::report::{export, figures};
use stt_ai::util::json::Json;

/// The STT-AI paper design points, built *through the trait registry*, must
/// match the hard-coded Table III anchors.
#[test]
fn stt_through_trait_reproduces_table3_anchors() {
    let sys = SystemConfig::paper_stt_ai().buffer_system();
    let area = sys.glb_arrays()[0].area_mm2();
    assert!((area - 1.01).abs() / 1.01 < 0.03, "{area}");
    let base = SystemConfig::paper_baseline().buffer_system();
    assert!((base.area_mm2() - 16.2).abs() / 16.2 < 0.02);
    // And the composed Table III savings still hold (same numbers as the
    // pre-trait build — table3 tests assert the tolerances).
    let rows = stt_ai::report::table3_rows();
    let (a, p) = rows[1].savings_vs(&rows[0]);
    assert!(a > 0.7 && p > 0.0, "area {a} power {p}");
}

/// A SOT-MRAM build of the same system config: legal, denser than SRAM,
/// write-cheaper than STT.
#[test]
fn sot_system_config_builds_and_orders() {
    let mut cfg = SystemConfig::paper_stt_ai();
    cfg.tech = TechConfig::new(TechBase::Sot);
    let sot = cfg.buffer_system();
    assert_eq!(sot.glb_arrays()[0].tech, TechnologyId::Sot);
    let stt = SystemConfig::paper_stt_ai().buffer_system();
    let sram = SystemConfig::paper_baseline().buffer_system();
    assert!(sot.area_mm2() > stt.area_mm2(), "2T SOT cell bigger than 1T STT");
    assert!(sot.area_mm2() < sram.area_mm2() / 4.0, "still far denser than SRAM");
    assert!(sot.glb_write_energy_j() < stt.glb_write_energy_j());
    // Variant structure is preserved: an Ultra config in SOT splits MSB/LSB.
    let mut ultra = SystemConfig::paper_stt_ai_ultra();
    ultra.tech = TechConfig::new(TechBase::Sot);
    assert_eq!(ultra.buffer_system().glb_arrays().len(), 2);
    assert_eq!(GlbVariant::SttAiUltra.kind_for(&ultra.tech).banks().len(), 2);
}

/// `sweep --tech sot` + a write_intensity axis: new records exist, export
/// round-trips through CSV and JSON, and the write-heavy regime flips the
/// technology ranking in SOT's favor.
#[test]
fn sot_and_write_intensity_records_export() {
    let zoo = engine::shared_zoo();
    let axes = engine::parse_axes(
        "model=ResNet50,variant=stt_ai,tech=stt|sot,write_intensity=1|3",
    )
    .unwrap();
    let results = Runner::new(2).run(engine::custom_spec(&zoo, axes));
    assert_eq!(results.len(), 4);

    let pick = |tech: &str, wi: f64| {
        results
            .iter()
            .find(|r| {
                r.point.tech.unwrap().name() == tech && r.point.write_intensity == Some(wi)
            })
            .unwrap()
    };
    // At inference intensity STT and SOT are close; at training intensity
    // SOT's cheap writes win outright.
    let gap_inf = pick("sakhare2020", 1.0).metric("buffer_energy_j")
        - pick("sot2023", 1.0).metric("buffer_energy_j");
    let gap_train = pick("sakhare2020", 3.0).metric("buffer_energy_j")
        - pick("sot2023", 3.0).metric("buffer_energy_j");
    assert!(gap_train > gap_inf, "SOT's edge must grow with write intensity");
    assert!(gap_train > 0.0);

    // Export: CSV rectangular, JSON parses, columns carry the new axes.
    let dir = std::env::temp_dir().join("stt_ai_tech_export_test");
    std::fs::create_dir_all(&dir).unwrap();
    let csv_path = dir.join("sot.csv");
    let json_path = dir.join("sot.json");
    export::write_results_csv(&csv_path, &results).unwrap();
    export::export_json(&json_path, &results).unwrap();
    let text = std::fs::read_to_string(&csv_path).unwrap();
    let mut lines = text.lines();
    let header = lines.next().unwrap();
    assert!(header.contains("tech") && header.contains("write_intensity"), "{header}");
    for l in lines {
        assert_eq!(l.split(',').count(), header.split(',').count(), "{l}");
    }
    let parsed = Json::parse(std::fs::read_to_string(&json_path).unwrap().trim()).unwrap();
    let arr = parsed.as_arr().unwrap();
    assert_eq!(arr.len(), 4);
    assert!(arr.iter().any(|r| {
        r.req("point").unwrap().get("tech").and_then(|t| t.as_str()) == Some("sot2023")
    }));
    std::fs::remove_dir_all(&dir).ok();
}

/// The cross-technology comparison renders for every registry entry and is
/// deterministic across worker counts (same contract as the figures).
#[test]
fn techcmp_renders_deterministically() {
    let render = |workers: usize| {
        let mut buf = Vec::new();
        figures::techcmp_with(&mut buf, &Runner::new(workers)).unwrap();
        String::from_utf8(buf).unwrap()
    };
    let serial = render(1);
    assert_eq!(serial, render(4), "techcmp must be worker-count invariant");
    for t in registry() {
        assert!(serial.contains(t.name()), "missing {} in:\n{serial}", t.name());
    }
    assert!(serial.contains("lowest buffer energy"));
}

/// CLI-facing token grammar: the `--tech` families resolve, and unknown
/// tokens fail closed everywhere.
#[test]
fn tech_token_grammar_is_consistent() {
    for (token, id) in [
        ("stt", TechnologyId::SttSakhare2020),
        ("sot", TechnologyId::Sot),
        ("sram", TechnologyId::Sram),
        ("wei2019", TechnologyId::SttWei2019),
    ] {
        assert_eq!(by_token(token).unwrap().id(), id);
        assert_eq!(TechBase::from_token(token).unwrap().id(), id);
    }
    assert!(by_token("fefet").is_none());
    assert!(TechBase::from_token("fefet").is_none());
    assert!(engine::parse_axes("tech=fefet").is_err());
}
