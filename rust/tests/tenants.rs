//! Integration coverage of multi-tenant SLO classes (EXPERIMENTS.md
//! §Multi-tenant serving): the committed golden mix parses equal to its
//! builtin, per-tenant ledgers are byte-identical across reruns and
//! `--parallel` values for every builtin mix, the hetero SRAM+Ultra
//! payoff gate holds (class-aware scheduling beats the single-queue
//! baseline on tight-class p99 at equal-ish energy), a `--record` log
//! replays to the byte-identical report, the default mix reproduces the
//! pre-tenant stack, and accuracy floors pin tenants to accurate shards.

use std::time::Duration;

use stt_ai::config::GlbVariant;
use stt_ai::coordinator::{
    ArrivalTrace, EngineSpec, FleetConfig, FleetSim, FleetSimReport, TenantMix,
};
use stt_ai::util::clock::Clock;
use stt_ai::util::json::Json;

const GOLDEN: &str = concat!(env!("CARGO_MANIFEST_DIR"), "/golden/fleet_tenants.mix.json");

fn run_trace(trace: ArrivalTrace, specs: Vec<EngineSpec>, cfg: FleetConfig) -> FleetSimReport {
    let mut sim = FleetSim::new(trace, specs, cfg).expect("fleet is non-empty");
    sim.run(&Clock::virtual_at_zero()).expect("fleet run")
}

fn hetero() -> Vec<EngineSpec> {
    vec![EngineSpec::paper(GlbVariant::Sram), EngineSpec::paper(GlbVariant::SttAiUltra)]
}

fn mix_cfg(mix: &TenantMix, requests: usize, parallel: usize) -> FleetConfig {
    FleetConfig { tenants: mix.clone(), requests, parallel, ..Default::default() }
}

/// The committed golden mix file is the two_tier builtin, field for field
/// — and serializes back to the identical canonical JSON.
#[test]
fn golden_mix_file_matches_the_builtin() {
    let parsed = TenantMix::parse(GOLDEN).expect("golden mix parses");
    let builtin = TenantMix::builtin("two_tier").unwrap();
    assert_eq!(parsed, builtin);
    assert_eq!(parsed.to_json().to_string(), builtin.to_json().to_string());
}

/// A fleet run booted from the golden mix file is byte-identical to one
/// booted from the builtin token (the CLI `--tenants FILE` contract).
#[test]
fn golden_mix_runs_byte_identical_to_the_builtin() {
    let trace = || ArrivalTrace::builtin("poisson").unwrap();
    let from_file = TenantMix::parse(GOLDEN).unwrap();
    let builtin = TenantMix::builtin("two_tier").unwrap();
    let a = run_trace(trace(), hetero(), mix_cfg(&from_file, 20_000, 1));
    let b = run_trace(trace(), hetero(), mix_cfg(&builtin, 20_000, 1));
    assert_eq!(a.render(), b.render());
    assert_eq!(a.to_json().to_string(), b.to_json().to_string());
}

/// Same mix + seed → byte-identical reports (tenant ledgers included)
/// across consecutive runs and `--parallel` worker counts, for every
/// builtin tenant mix.
#[test]
fn tenant_reports_are_byte_identical_across_reruns_and_parallel() {
    for name in TenantMix::builtin_names() {
        let mix = TenantMix::builtin(name).unwrap();
        let trace = || ArrivalTrace::builtin("poisson").unwrap();
        let a = run_trace(trace(), EngineSpec::paper_fleet(3), mix_cfg(&mix, 30_000, 1));
        let b = run_trace(trace(), EngineSpec::paper_fleet(3), mix_cfg(&mix, 30_000, 1));
        let c = run_trace(trace(), EngineSpec::paper_fleet(3), mix_cfg(&mix, 30_000, 4));
        assert_eq!(a.render(), b.render(), "{name}: consecutive runs diverged");
        assert_eq!(a.render(), c.render(), "{name}: --parallel leaked into the report");
        assert_eq!(a.to_json().to_string(), c.to_json().to_string(), "{name}");
        assert_eq!(a.offered, 30_000, "{name}");
        let expect_tenants = if mix.is_default() { 0 } else { mix.tenants.len() };
        assert_eq!(a.tenants.len(), expect_tenants, "{name}");
        for t in &a.tenants {
            assert_eq!(t.arrived, t.served + t.rejected, "{name}/{}: ledger leak", t.name);
        }
        assert_eq!(
            a.tenants.iter().map(|t| t.arrived).sum::<u64>(),
            if mix.is_default() { 0 } else { a.offered },
            "{name}: arrivals book to exactly one tenant"
        );
    }
}

/// The payoff gate: on a heterogeneous SRAM+Ultra pair under the builtin
/// two-tenant mix, class-aware scheduling must beat the single-queue
/// baseline on tight-class p99 while fleet energy per request stays
/// within 5 % — the SRAM island earns its area for the 2 ms class, the
/// Ultra island keeps the energy win for the 50 ms class.
#[test]
fn hetero_two_tier_beats_the_single_queue_baseline() {
    let mix = TenantMix::builtin("two_tier").unwrap();
    let trace = || ArrivalTrace::builtin("poisson").unwrap();
    let aware = run_trace(trace(), hetero(), mix_cfg(&mix, 30_000, 1));
    let aware4 = run_trace(trace(), hetero(), mix_cfg(&mix, 30_000, 4));
    let baseline = run_trace(
        trace(),
        hetero(),
        FleetConfig { classless: true, ..mix_cfg(&mix, 30_000, 1) },
    );
    assert_eq!(aware.render(), aware4.render(), "--parallel is cosmetic");
    // Both runs ledger the same tenants against the same per-class SLOs.
    assert_eq!(aware.tenants.len(), 2);
    assert_eq!(baseline.tenants.len(), 2);
    let tight = &aware.tenants[0];
    let tight_base = &baseline.tenants[0];
    assert_eq!(tight.name, "tight");
    assert_eq!(tight_base.name, "tight");
    assert!(tight.served > 0 && tight_base.served > 0);
    assert!(
        tight.p99_us < tight_base.p99_us,
        "tight p99 {}us must beat the single-queue baseline's {}us",
        tight.p99_us,
        tight_base.p99_us
    );
    assert!(
        aware.mean_uj <= baseline.mean_uj * 1.05,
        "fleet energy {:.3}uJ/req must stay within 5% of the baseline's {:.3}uJ/req",
        aware.mean_uj,
        baseline.mean_uj
    );
}

/// `--record` → replay round trip: a recorded run's JSON-lines log, fed
/// back through `ArrivalTrace::parse`, reproduces the byte-identical
/// report — arrivals, routing, batching, energy and all.
#[test]
fn record_log_replays_to_the_byte_identical_report() {
    let cfg = FleetConfig { requests: 2_000, record: true, ..Default::default() };
    let trace = ArrivalTrace::builtin("bursty").unwrap();
    let mut sim = FleetSim::new(trace, hetero(), cfg.clone()).unwrap();
    let first = sim.run(&Clock::virtual_at_zero()).unwrap();
    let log = sim.render_record();
    assert_eq!(log.lines().count(), 2_001, "header + one line per request");
    let path = std::env::temp_dir()
        .join(format!("stt_ai_tenants_record_{}.jsonl", std::process::id()));
    std::fs::write(&path, &log).unwrap();
    let replay = ArrivalTrace::parse(path.to_str().unwrap()).expect("recording parses");
    std::fs::remove_file(&path).ok();
    let again = run_trace(replay, hetero(), cfg);
    assert_eq!(again.render(), first.render());
    assert_eq!(again.to_json().to_string(), first.to_json().to_string());
}

/// Migration golden: the default single-tenant mix takes the legacy code
/// paths — explicitly forcing `classless` changes nothing, and the report
/// carries no tenant section.
#[test]
fn default_mix_reproduces_the_pre_tenant_stack() {
    let trace = || ArrivalTrace::builtin("diurnal").unwrap();
    let plain = run_trace(trace(), EngineSpec::paper_fleet(3), FleetConfig::default());
    let classless = run_trace(
        trace(),
        EngineSpec::paper_fleet(3),
        FleetConfig { classless: true, ..Default::default() },
    );
    let default_mix = run_trace(
        trace(),
        EngineSpec::paper_fleet(3),
        FleetConfig { tenants: TenantMix::single_default(), ..Default::default() },
    );
    assert_eq!(plain.render(), classless.render());
    assert_eq!(plain.render(), default_mix.render());
    assert_eq!(plain.to_json().to_string(), default_mix.to_json().to_string());
    assert!(plain.tenants.is_empty());
    assert!(!plain.to_json().to_string().contains("\"tenants\""));
}

/// Accuracy floors pin classes to accurate shards: under three_class on
/// SRAM+Ultra, every tight-tenant request (floor 0.999) serves on the
/// SRAM shard (est. accuracy 1.0), never the Ultra (0.995) — verified
/// per request from the record log.
#[test]
fn accuracy_floor_keeps_the_tight_class_on_accurate_shards() {
    let mix = TenantMix::builtin("three_class").unwrap();
    let cfg = FleetConfig { record: true, ..mix_cfg(&mix, 20_000, 1) };
    let trace = ArrivalTrace::builtin("poisson").unwrap();
    let mut sim = FleetSim::new(trace, hetero(), cfg).unwrap();
    let r = sim.run(&Clock::virtual_at_zero()).unwrap();
    assert!(r.tenants[0].served > 0, "tight class saw traffic");
    let mut tight_rows = 0u64;
    for line in sim.render_record().lines().skip(1) {
        let row = Json::parse(line).expect("record row parses");
        let tenant = row.get("tenant").and_then(Json::as_u64).unwrap();
        let engine = row.get("engine").and_then(Json::as_u64).unwrap();
        if tenant == 0 {
            tight_rows += 1;
            assert_eq!(engine, 0, "tight request served off the accurate island: {line}");
        }
    }
    assert_eq!(tight_rows, r.tenants[0].served, "log covers every tight completion");
}

/// Per-tenant SLOs drive the ledgers: the tight class's 2 ms target is
/// scored per tenant even when the fleet-level SLO is far looser.
#[test]
fn tenant_ledgers_score_each_class_own_slo() {
    let mix = TenantMix::builtin("two_tier").unwrap();
    let r = run_trace(ArrivalTrace::builtin("poisson").unwrap(), hetero(), mix_cfg(&mix, 20_000, 1));
    assert_eq!(r.tenants[0].slo, Duration::from_millis(2));
    assert_eq!(r.tenants[1].slo, Duration::from_millis(50));
    let text = r.render();
    assert!(text.contains("tenant tight [tight] w=4:"), "{text}");
    assert!(text.contains("tenant relaxed [relaxed] w=1:"), "{text}");
    let j = r.to_json().to_string();
    assert!(j.contains("\"tenants\":[{"), "{j}");
    assert!(j.contains("\"slo_ms\":2"), "{j}");
}
