//! Integration coverage of the discrete-event fleet simulator
//! (EXPERIMENTS.md §Fleet simulation): the committed golden trace parses
//! equal to its builtin, fleet reports are byte-identical across reruns
//! and `--parallel` values for every builtin trace at 1e5 requests, the
//! Poisson generator hits its configured rate, a heterogeneous SRAM+Ultra
//! fleet beats the all-Ultra fleet on p99 under bursty load, the
//! autoscaler reacts to queue pressure, and the `[traffic]` config
//! section feeds the same run as the builtin token.

use stt_ai::config::{GlbVariant, SystemConfig};
use stt_ai::coordinator::{
    ArrivalGen, ArrivalTrace, EngineSpec, FleetConfig, FleetSim, FleetSimReport,
};
use stt_ai::util::clock::Clock;

const GOLDEN: &str = concat!(env!("CARGO_MANIFEST_DIR"), "/golden/fleet_diurnal.trace.json");

fn run_trace(trace: ArrivalTrace, specs: Vec<EngineSpec>, cfg: FleetConfig) -> FleetSimReport {
    let mut sim = FleetSim::new(trace, specs, cfg).expect("fleet is non-empty");
    sim.run(&Clock::virtual_at_zero()).expect("fleet run")
}

fn cfg_with(requests: usize, parallel: usize) -> FleetConfig {
    FleetConfig { requests, parallel, ..Default::default() }
}

/// Every request is accounted for exactly once, and the per-engine served
/// counts cover the fleet total.
fn accounting_closes(r: &FleetSimReport) {
    assert_eq!(r.offered, r.served + r.rejected + r.malformed, "accounting leak in {}", r.trace);
    let per_engine: u64 = r.engines.iter().map(|e| e.served).sum();
    assert_eq!(r.served, per_engine, "engine ledger mismatch in {}", r.trace);
}

/// The committed golden trace file is the diurnal builtin, field for
/// field — and serializes back to the identical canonical JSON.
#[test]
fn golden_trace_file_matches_the_builtin() {
    let parsed = ArrivalTrace::parse(GOLDEN).expect("golden trace parses");
    let builtin = ArrivalTrace::builtin("diurnal").unwrap();
    assert_eq!(parsed, builtin);
    assert_eq!(parsed.to_json().to_string(), builtin.to_json().to_string());
}

/// Same trace + seed → byte-identical reports across consecutive runs and
/// across `--parallel` worker counts, for every builtin trace at 1e5
/// simulated requests (the acceptance gate for the simulator being
/// deterministic, not merely statistically similar).
#[test]
fn reports_are_byte_identical_across_reruns_and_parallel() {
    for name in ArrivalTrace::builtin_names() {
        let trace = || ArrivalTrace::builtin(name).unwrap();
        let a = run_trace(trace(), EngineSpec::paper_fleet(3), cfg_with(100_000, 1));
        let b = run_trace(trace(), EngineSpec::paper_fleet(3), cfg_with(100_000, 1));
        let c = run_trace(trace(), EngineSpec::paper_fleet(3), cfg_with(100_000, 4));
        assert_eq!(a.render(), b.render(), "{name}: consecutive runs diverged");
        assert_eq!(a.render(), c.render(), "{name}: --parallel leaked into the report");
        assert_eq!(a.to_json().to_string(), c.to_json().to_string(), "{name}");
        accounting_closes(&a);
        assert_eq!(a.offered, 100_000, "{name}");
        assert!(a.served > 0, "{name}: fleet served nothing");
    }
}

/// The Poisson generator's empirical inter-arrival mean matches the
/// configured rate at 1e5 events (±2 %, ≈ 6σ of the sample mean).
#[test]
fn poisson_interarrival_mean_matches_the_configured_rate() {
    let trace = ArrivalTrace::builtin("poisson").unwrap();
    let mut gen = ArrivalGen::new(&trace);
    let n = 100_000u64;
    let mut last = std::time::Duration::ZERO;
    for _ in 0..n {
        last = gen.next_offset();
    }
    let mean_us = last.as_secs_f64() * 1e6 / n as f64;
    let expect_us = 1e6 / 14_000.0;
    let err = (mean_us - expect_us).abs() / expect_us;
    assert!(err < 0.02, "poisson mean {mean_us:.3}us vs {expect_us:.3}us (err {err:.4})");
}

/// The hetero-fleet gate: under the bursty trace (40 k req/s storms), a
/// mixed SRAM+Ultra fleet — whose fast island absorbs SLO-threatened
/// requests — holds a strictly lower p99 than two Ultra engines, whose
/// combined 32 k req/s capacity falls behind every burst.
#[test]
fn hetero_sram_island_beats_all_ultra_on_p99_under_bursty_load() {
    let bursty = || ArrivalTrace::builtin("bursty").unwrap();
    let mixed =
        vec![EngineSpec::paper(GlbVariant::Sram), EngineSpec::paper(GlbVariant::SttAiUltra)];
    let a = run_trace(bursty(), mixed, cfg_with(30_000, 1));
    let b = run_trace(bursty(), EngineSpec::paper_fleet(2), cfg_with(30_000, 1));
    accounting_closes(&a);
    accounting_closes(&b);
    assert!(
        a.p99_us < b.p99_us,
        "mixed fleet p99 {}us !< all-Ultra p99 {}us",
        a.p99_us,
        b.p99_us
    );
}

/// With autoscaling on, burst pressure must activate reserve engines (a
/// scale-up with a paid warm-up), and the ledger still closes.
#[test]
fn autoscaler_reacts_to_burst_pressure() {
    let trace = ArrivalTrace::builtin("bursty").unwrap();
    let mut cfg = cfg_with(30_000, 1);
    cfg.autoscale = true;
    let r = run_trace(trace, EngineSpec::paper_fleet(4), cfg);
    accounting_closes(&r);
    assert!(r.scale_ups >= 1, "burst load must activate reserve engines");
    assert!(r.active_end >= 1);
    assert!(r.engines.iter().any(|e| e.warm_boots > 0), "activation pays a warm-up");
}

/// A `[traffic]` section in a SystemConfig drives the identical run as the
/// builtin token it carries.
#[test]
fn config_traffic_section_feeds_the_fleet_run() {
    let mut cfg = SystemConfig::paper_stt_ai_ultra();
    cfg.traffic = Some(ArrivalTrace::builtin("uniform").unwrap());
    let back = SystemConfig::from_json(&cfg.to_json()).expect("config roundtrip");
    let trace = back.traffic.expect("traffic section survives the roundtrip");
    let a = run_trace(trace, EngineSpec::paper_fleet(3), cfg_with(5_000, 1));
    let b = run_trace(
        ArrivalTrace::builtin("uniform").unwrap(),
        EngineSpec::paper_fleet(3),
        cfg_with(5_000, 1),
    );
    assert_eq!(a.render(), b.render());
    assert_eq!(a.to_json().to_string(), b.to_json().to_string());
}
