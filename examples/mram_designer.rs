//! MRAM designer: walk one customized STT-MRAM design end to end, including
//! the PT-corner analysis and the adjustable write driver of Fig. 9.
//!
//! Run: `cargo run --release --example mram_designer [retention_s] [ber]`

use stt_ai::mram::{
    read_disturb_prob, retention_failure_prob, write_error_rate, DesignTargets, MtjTech,
    PtCorner, PtmSample, ScalingSolver, WriteDriver,
};
use stt_ai::util::units::fmt_time;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let retention: f64 = args.get(1).map(|s| s.parse()).transpose()?.unwrap_or(3.0);
    let ber: f64 = args.get(2).map(|s| s.parse()).transpose()?.unwrap_or(1e-8);

    let tech = MtjTech::sakhare2020();
    let solver = ScalingSolver::new(tech);
    let targets = DesignTargets {
        retention_time: retention,
        retention_ber: ber,
        read_disturb_ber: ber,
        write_ber: ber,
    };
    let d = solver.solve(&targets);

    println!("== design point: {} @ BER {ber:.0e} ({}) ==", fmt_time(retention), tech.name);
    println!("Δ_scaled {:.2} → Δ_PT_GB {:.2} → Δ_PT_MAX {:.2}", d.delta_scaled, d.delta_guard_banded, d.delta_pt_max);
    println!("write pulse {}  read pulse {}", fmt_time(d.write_pulse), fmt_time(d.read_pulse));

    // Verify the reliability budget at every PT corner.
    println!("\n== corner verification ==");
    let v = solver.variation;
    for corner in PtCorner::ALL {
        let delta = corner.delta(&v, d.delta_guard_banded);
        let p_rf = retention_failure_prob(retention, tech.tau_ret, delta);
        let p_rd = read_disturb_prob(d.read_pulse, tech.tau_rd, delta, tech.read_ratio);
        let wer = write_error_rate(d.write_pulse, tech.tau_w, delta, d.overdrive);
        println!(
            "{corner:?}: Δ_eff={delta:.1}  P_RF={p_rf:.2e}  P_RD={p_rd:.2e}  WER={wer:.2e}"
        );
    }

    // The Fig. 9 adjustable write driver across the PTM operating map.
    println!("\n== adjustable write driver (Fig. 9), 4 extra legs ==");
    let params = tech.params_at_delta(d.delta_guard_banded);
    let driver = WriteDriver::new(v, d.delta_guard_banded, d.overdrive, params.critical_current(), 4, 0.9);
    for (sigma, temp) in [(0.0, 300.0), (2.0, 273.0), (4.0, 253.0), (-4.0, 393.0)] {
        let s = PtmSample { process_sigma: sigma, temperature: temp };
        match driver.legs_for(&s) {
            Some(legs) => println!(
                "  σ={sigma:+.0} T={temp:.0}K → {legs} extra legs, I_w={:.1} µA, E_w={:.3} pJ",
                driver.supplied_current(legs) * 1e6,
                driver.write_energy(&s, d.write_pulse).unwrap() * 1e12
            ),
            None => println!("  σ={sigma:+.0} T={temp:.0}K → OUT OF SPEC (write driver exhausted)"),
        }
    }
    println!(
        "\ntypical-corner energy saving vs worst-case-sized driver: {:.1}%",
        driver.typical_saving_fraction(d.write_pulse) * 100.0
    );
    Ok(())
}
