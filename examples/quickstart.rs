//! Quickstart: the whole stack in ~60 lines.
//!
//! 1. Solve a customized STT-MRAM design point from an occupancy target.
//! 2. Compose the STT-AI buffer system and compare it with the SRAM baseline.
//! 3. Load the AOT TinyCNN artifact and run one fault-injected inference.
//!
//! Run: `make artifacts && cargo run --release --example quickstart`

use std::path::Path;

use stt_ai::config::GlbVariant;
use stt_ai::coordinator::{Engine, EngineConfig};
use stt_ai::memsys::BufferSystem;
use stt_ai::mram::{DesignTargets, MtjTech, ScalingSolver};
use stt_ai::util::units::fmt_time;

fn main() -> anyhow::Result<()> {
    // -- 1. Device-level co-design: GLB retention 3 s @ BER 1e-8 (§V.C).
    let solver = ScalingSolver::new(MtjTech::sakhare2020());
    let glb = solver.solve(&DesignTargets::global_buffer());
    println!(
        "GLB MRAM design: Δ={:.1} (guard-banded {:.1})",
        glb.delta_scaled, glb.delta_guard_banded
    );
    println!(
        "  write pulse {}  read pulse {}",
        fmt_time(glb.write_pulse),
        fmt_time(glb.read_pulse)
    );
    println!("  write energy {:.2}x of the 10-year base cell", glb.rel_write_energy);

    // -- 2. System-level: buffer area/leakage vs the SRAM baseline.
    let baseline = BufferSystem::baseline_12mb();
    let stt_ai = BufferSystem::stt_ai_12mb();
    println!(
        "\n12 MB buffer: SRAM {:.2} mm² vs STT-MRAM(+scratchpad) {:.2} mm²  ({:.1}x denser)",
        baseline.area_mm2(),
        stt_ai.area_mm2(),
        baseline.area_mm2() / stt_ai.area_mm2()
    );

    // -- 3. Serve one batch through the AOT artifact with the Ultra fault model.
    let artifacts = Path::new("artifacts");
    if !artifacts.join("manifest.json").exists() {
        println!("\n(artifacts/ missing — run `make artifacts` for the inference demo)");
        return Ok(());
    }
    let engine = Engine::load(artifacts, EngineConfig::new(GlbVariant::SttAiUltra))?;
    let model = engine.model_for_batch(1)?;
    let (images, labels) = engine.manifest.load_testset()?;
    let per_image: usize = engine.manifest.testset.image_shape.iter().product::<i64>() as usize;
    let logits = engine.infer(&model, &images[..per_image])?;
    let pred = model.predictions(&logits)[0];
    println!(
        "\nTinyCNN on PJRT: predicted class {pred} (label {}), {} bit flips injected",
        labels[0], engine.flips
    );
    Ok(())
}
