//! Open-loop serving demo: a producer thread feeds requests at a target
//! rate through the dynamic batcher while the engine drains them — the
//! vLLM-router-shaped view of the coordinator (threaded; the build is
//! offline so no async runtime, the loop structure is identical).
//!
//! Both threads read the same wall-backed [`Clock`], the injectable time
//! source the whole serving stack runs on (`Clock::virtual_at_zero()` is
//! what the chaos harness substitutes for deterministic replays).
//!
//! Run: `make artifacts && cargo run --release --example serve [rate_rps]`

use std::path::Path;
use std::sync::mpsc;
use std::sync::Arc;
use std::time::Duration;

use stt_ai::config::GlbVariant;
use stt_ai::coordinator::{Batcher, Engine, EngineConfig, Metrics, Request};
use stt_ai::util::clock::Clock;

const N_REQUESTS: usize = 512;
const BATCH: usize = 16;

fn main() -> anyhow::Result<()> {
    let rate: f64 = std::env::args().nth(1).map(|s| s.parse()).transpose()?.unwrap_or(2000.0);
    let artifacts = Path::new("artifacts");
    let engine = Engine::load(artifacts, EngineConfig::new(GlbVariant::SttAiUltra))?;
    let model = engine.model_for_batch(BATCH)?;
    let (images, _) = engine.manifest.load_testset()?;
    let per_image: usize = engine.manifest.testset.image_shape.iter().product::<i64>() as usize;
    let n_test = engine.manifest.testset.n;

    let clock = Arc::new(Clock::wall());

    // Producer: one request every 1/rate seconds, stamped off the shared
    // serving clock.
    let (tx, rx) = mpsc::channel::<Request>();
    let producer = {
        let clock = Arc::clone(&clock);
        std::thread::spawn(move || {
            let gap = Duration::from_secs_f64(1.0 / rate);
            for i in 0..N_REQUESTS {
                let src = i % n_test;
                let img = images[src * per_image..(src + 1) * per_image].to_vec();
                if tx.send(Request::new(i as u64, img, clock.now())).is_err() {
                    break;
                }
                std::thread::sleep(gap);
            }
        })
    };

    // Consumer: batcher + engine.
    let mut batcher = Batcher::new(BATCH, Duration::from_millis(2), per_image, 4096);
    let mut metrics = Metrics::new();
    let mut served = 0usize;
    while served < N_REQUESTS {
        // Drain whatever has arrived.
        while let Ok(r) = rx.try_recv() {
            batcher.push(r);
        }
        let now = clock.now();
        if batcher.ready(now) {
            if let Some(b) = batcher.form(BATCH, now) {
                let t0 = clock.now();
                let _ = engine.infer(&model, &b.images)?;
                let done = clock.now();
                metrics.record_batch_waited(
                    done,
                    b.real,
                    b.capacity,
                    done.duration_since(t0),
                    b.oldest_wait,
                );
                served += b.real;
            }
        } else {
            std::thread::sleep(Duration::from_micros(100));
        }
    }
    producer.join().ok();

    println!("open-loop @ {rate:.0} req/s target: {}", metrics.summary());
    println!("sustained throughput {:.1} req/s", metrics.throughput());
    Ok(())
}
