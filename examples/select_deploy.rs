//! Sweep-driven deployment selection: derive the serving design point from
//! the DSE instead of hard-coding a paper config, then show the exact
//! configuration the coordinator would boot from.
//!
//! Run: `cargo run --release --example select_deploy [objective]`
//! (objective: area | energy | latency | throughput; default area)
//!
//! This is the codesign loop end-to-end:
//!   candidate grid (variant x delta x ber)  ->  constraints + Pareto
//!   frontier  ->  DesignSelection  ->  SystemConfig / EngineConfig.

use stt_ai::coordinator::EngineConfig;
use stt_ai::dse::engine::{shared_zoo, Runner};
use stt_ai::dse::select::{self, Constraint, Objective};

fn main() -> anyhow::Result<()> {
    let objective = match std::env::args().nth(1) {
        Some(tok) => Objective::from_token(&tok)
            .ok_or_else(|| anyhow::anyhow!("unknown objective {tok:?}"))?,
        None => Objective::MinArea,
    };
    let constraints = [Constraint::MinAccuracy(0.99), Constraint::RetentionCoversOccupancy];

    let zoo = shared_zoo();
    let runner = Runner::auto();
    let results = runner.run(select::spec_selection(&zoo));
    println!(
        "evaluated {} candidates on {} workers (objective: {})",
        results.len(),
        runner.workers(),
        objective.token()
    );

    let sel = select::select("selection", &results, objective, &constraints)?;
    println!(
        "selected {} (feasible {}/{}, frontier {}):",
        sel.variant().label(),
        sel.feasible,
        sel.candidates,
        sel.frontier
    );
    for (k, v) in sel.point.columns() {
        println!("  point  {k:<10} = {v}");
    }
    for (k, v) in &sel.metrics {
        println!("  metric {k:<22} = {v:.6e}");
    }
    if let Some(saving) = sel.metric("area_saving_vs_sram") {
        println!("  area saving vs SRAM baseline: {:.1}%", saving * 100.0);
    }

    // The serving bridge: this is everything `stt-ai serve --from-selection`
    // derives — no GlbVariant is hard-coded between here and the engine.
    let cfg = sel.system_config();
    println!("system config: {} (GLB {:?}, {} B)", cfg.name, cfg.glb, cfg.glb_bytes);
    let engine_cfg = EngineConfig::from_selection(&sel);
    println!(
        "engine fault model: msb_ber={:e} lsb_ber={:e} seed={:#x}",
        engine_cfg.ber.msb_ber, engine_cfg.ber.lsb_ber, engine_cfg.seed
    );
    println!("glb structure: {:?}", sel.glb_kind());
    Ok(())
}
