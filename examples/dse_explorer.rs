//! DSE explorer: regenerates the paper's design-space figures (10–19) and
//! then answers the co-design question the paper's §V works through:
//! "what GLB capacity, Δ, and scratchpad should an accelerator of THIS
//! array size and batch use?"
//!
//! Run: `cargo run --release --example dse_explorer [macs] [batch]`

use std::io::Write;

use stt_ai::accel::{ArrayConfig, RetentionAnalysis};
use stt_ai::dse::capacity;
use stt_ai::models::{self, DType};
use stt_ai::mram::{DesignTargets, MtjTech, ScalingSolver};
use stt_ai::report;
use stt_ai::util::units::{fmt_bytes, fmt_time, KB, MB};

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let macs: u64 = args.get(1).map(|s| s.parse()).transpose()?.unwrap_or(42);
    let batch: u64 = args.get(2).map(|s| s.parse()).transpose()?.unwrap_or(16);

    let mut out = std::io::stdout().lock();
    writeln!(out, "#### paper figures ####")?;
    report::fig10(&mut out)?;
    report::fig11(&mut out)?;
    report::fig13(&mut out)?;
    report::fig14(&mut out)?;
    report::fig16(&mut out)?;
    report::fig18(&mut out)?;
    report::fig19(&mut out)?;

    writeln!(out, "\n#### co-design for a {macs}x{macs}-MAC array, batch {batch} ####")?;
    let array = ArrayConfig::with_mac_array(macs);
    let zoo = models::zoo();

    // 1. GLB capacity that serves most of the zoo without DRAM spill.
    let mut caps: Vec<u64> = zoo.iter().map(|m| m.max_conv_working_set(DType::Bf16, batch)).collect();
    caps.sort();
    let p80 = caps[(caps.len() * 4) / 5];
    writeln!(out, "GLB capacity for 80% zoo coverage: {}", fmt_bytes(p80))?;
    let served = capacity::models_served(&zoo, DType::Bf16, batch, 12 * MB);
    writeln!(out, "a 12 MB GLB serves {served}/19 models at bf16/batch {batch}")?;

    // 2. Worst occupancy → Δ design with margin.
    let ra = RetentionAnalysis::new(&array, batch);
    let worst = zoo.iter().map(|m| ra.analyze(m).max_t_ret()).fold(0.0, f64::max);
    writeln!(out, "worst GLB occupancy: {}", fmt_time(worst))?;
    let solver = ScalingSolver::new(MtjTech::sakhare2020());
    let targets = DesignTargets {
        retention_time: 2.0 * worst, // 2x engineering margin
        retention_ber: 1e-8,
        read_disturb_ber: 1e-8,
        write_ber: 1e-8,
    };
    let d = solver.solve(&targets);
    writeln!(
        out,
        "=> Δ_scaled {:.1}, Δ_PT_GB {:.1}, write pulse {}, {:.2}x base write energy",
        d.delta_scaled,
        d.delta_guard_banded,
        fmt_time(d.write_pulse),
        d.rel_write_energy
    )?;

    // 3. Scratchpad sizing: cover 80% of the zoo's partial ofmaps.
    let mut partials: Vec<u64> = zoo.iter().map(|m| m.max_partial_ofmap(DType::Bf16)).collect();
    partials.sort();
    let sp = partials[(partials.len() * 4) / 5];
    writeln!(
        out,
        "scratchpad for 80% coverage: {} (paper picked {} )",
        fmt_bytes(sp),
        fmt_bytes(52 * KB)
    )?;
    Ok(())
}
