//! End-to-end driver (the EXPERIMENTS.md §E2E run): proves all layers
//! compose on a real small workload.
//!
//! * L1/L2 (build time): TinyCNN was trained for 400 steps on the synthetic
//!   10-class dataset and its Pallas forward lowered to HLO (`make
//!   artifacts`; loss curve recorded in artifacts/manifest.json).
//! * L3 (this binary): loads the artifact, runs the Fig. 21 grid — all
//!   three GLB variants × {dense, 50%-pruned} — through PJRT with the
//!   bank-split BER fault model, then a closed-loop serving run with
//!   latency/throughput metrics, then prints the Table III composition the
//!   accuracy numbers pair with.
//!
//! Run: `make artifacts && cargo run --release --example e2e_sttai`

use std::path::Path;

use stt_ai::config::GlbVariant;
use stt_ai::coordinator::{accuracy, serve, Engine, EngineConfig};
use stt_ai::report;

fn main() -> anyhow::Result<()> {
    let artifacts = Path::new("artifacts");

    // Training metadata recorded by the build.
    let engine = Engine::load(artifacts, EngineConfig::new(GlbVariant::Sram))?;
    let meta = &engine.manifest.train_meta;
    println!("== build-time training (L2, ref path) ==");
    if let (Some(steps), Some(acc)) = (meta.get("steps"), meta.get("test_acc")) {
        println!("  {steps} Adam steps, held-out accuracy {acc}");
    }
    if let Some(curve) = meta.get("loss_curve").and_then(|c| c.as_arr()) {
        let pts: Vec<String> = curve
            .iter()
            .filter_map(|p| p.as_arr())
            .map(|p| {
                format!(
                    "{}:{:.3}",
                    p[0].as_u64().unwrap_or(0),
                    p[1].as_f64().unwrap_or(0.0)
                )
            })
            .collect();
        println!("  loss curve (step:loss): {}", pts.join(" "));
    }
    drop(engine);

    // Fig. 21 grid: three variants × two prune rates, full test set.
    println!("\n== Fig. 21 reproduction (accuracy under STT-MRAM BER) ==");
    for prune in [0.0, 0.5] {
        let row = accuracy::fig21_row(artifacts, prune, 16, None)?;
        println!("-- prune rate {prune}");
        for r in [&row.baseline, &row.stt_ai, &row.stt_ai_ultra] {
            println!(
                "   {:<14} top1 {:.4}  top5 {:.4}  flips {:>4}  (n={})",
                r.variant, r.top1, r.top5, r.bit_flips, r.n
            );
        }
        let drop_pct = row.ultra_drop_normalized() * 100.0;
        println!("   Ultra normalized top-1 drop: {drop_pct:.3}% (paper: <1%)");
        anyhow::ensure!(drop_pct < 2.0, "Ultra accuracy drop out of the paper's band");
    }

    // Serving: closed-loop batched inference, latency/throughput.
    println!("\n== serving (L3 coordinator, batch 16) ==");
    let engine = Engine::load(artifacts, EngineConfig::new(GlbVariant::SttAiUltra))?;
    let summary = serve::closed_loop(&engine, 512, 16)?;
    println!("  {summary}");

    // The hardware the accuracy numbers pair with (Table III).
    println!("\n== Table III composition ==");
    let rows = report::table3_rows();
    let base = rows[0].clone();
    for r in &rows {
        let (a, p) = r.savings_vs(&base);
        println!(
            "  {:<18} {:>7.2} mm²  {:>8.2} mW   ({:>5.1}% area, {:>4.1}% power saving)",
            r.name,
            r.area_mm2,
            r.total_power_mw(),
            a * 100.0,
            p * 100.0
        );
    }
    println!("\nE2E OK");
    Ok(())
}
